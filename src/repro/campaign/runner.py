"""Campaign execution: expand the grid, plan once per workload, run jobs
in parallel, stream results, share one persistent (H, C, R) cache.

Execution is **plan-based**: every ``(workload, fidelity, slicer)`` is
parsed and sliced exactly once (a :class:`~repro.core.pipeline.PredictionPlan`
built by the :class:`~repro.campaign.plans.PlanStore`), and each grid
point only runs the cheap evaluate phase against its shared plan — with
all region latencies fetched in one batched cache operation.

Executors:

  * ``serial``  — in-process, deterministic schedule order;
  * ``thread``  — ThreadPoolExecutor; jobs share one live cache store, so a
    fingerprint evaluated by one job is a hit for every later job;
  * ``process`` — ProcessPoolExecutor.  Workers receive pickled *plan
    files* (never raw workload text) and unpickle only the plans their
    jobs reference.  With a ``cache_path``, every worker opens the same
    file-locked append-log store: misses are written through immediately
    and lookups tail the log, so workers observe each other's fresh
    entries *mid-campaign*.  Without a path, each worker falls back to a
    startup snapshot, ships its fresh entries back for the parent to
    merge, and chain siblings are warmed with their leader's entries.

Schedules (``schedule=``):

  * ``locality`` (default) — jobs are grouped into *cache chains*
    (identical (H, C, R) keysets: same plan + system + estimator); each
    chain's leader runs before its siblings are released, so parallel
    executors never duplicate a cold miss, and chains are ordered
    fingerprint-heavy-first so expensive workloads warm the shared cache
    before cheap ones;
  * ``grid``  — pure grid order, all jobs released at once (the legacy
    behavior).

Results stream to ``results.jsonl`` as jobs finish (crash-safe: a killed
campaign keeps everything completed so far), then consolidate into
``results.csv`` and ``summary.json``.
"""
from __future__ import annotations

import csv
import json
import os
import threading
import time
from concurrent.futures import (FIRST_COMPLETED, Executor, Future,
                                ProcessPoolExecutor, ThreadPoolExecutor,
                                wait)
from dataclasses import dataclass, field

from ..core.catalog import SystemRegistry, default_registry
from ..core.estimators.cache import PersistentCache
from ..core.pipeline import PredictionJob, PredictionPlan, Workload
from ..core.registry import ESTIMATORS, TOPOLOGIES, BuildContext
from ..serve import faults
from .builders import (build_estimator, build_system, build_topology,
                       build_workload)
from .plans import PlanStore
from .spec import CampaignSpec, JobSpec
from .summary import summarize

EXECUTORS = ("serial", "thread", "process")
SCHEDULES = ("locality", "grid")

# -------------------------- single-job execution --------------------------


@dataclass
class _Registries:
    """The registry set one campaign's jobs build against — a session's
    scoped registries (plugin kinds, user catalogs) or the globals —
    plus the spec file's base dir for backend-relative paths."""
    estimators: object = None           # core.registry.Registry
    topologies: object = None
    systems: SystemRegistry | None = None
    base_dir: str | None = None

    @classmethod
    def for_session(cls, session, spec: CampaignSpec) -> "_Registries":
        return cls(
            estimators=getattr(session, "estimators", None),
            topologies=getattr(session, "topologies", None),
            systems=spec.system_registry(getattr(session, "systems", None)),
            base_dir=spec.base_dir)

    def local_entries(self) -> tuple[dict, dict, dict, str | None]:
        """The non-global registrations, as picklable maps — what ships
        to process-pool workers so they can rebuild the same scope.
        (Classes pickle by reference: a plugin class must be importable
        from the worker, i.e. defined at module level — checked here, at
        the ship point, so the failure is one actionable error instead
        of a pickling traceback from inside the pool.)"""
        problems: list[str] = []
        for reg in (self.estimators, self.topologies):
            if reg is not None and hasattr(reg, "portability_errors"):
                problems.extend(reg.portability_errors())
        if problems:
            raise ValueError(
                "session-scoped backend classes cannot cross the "
                "worker-process boundary:\n  - " + "\n  - ".join(problems))
        est = self.estimators.local_entries() if self.estimators else {}
        topo = self.topologies.local_entries() if self.topologies else {}
        sysd: dict = {}
        chain = []
        reg = self.systems
        while reg is not None and reg is not default_registry():
            chain.append(reg)
            reg = reg.parent
        for r in reversed(chain):       # outermost scope wins
            sysd.update(r.local_systems())
        return est, topo, sysd, self.base_dir

    @classmethod
    def from_local_entries(cls, est: dict, topo: dict, sysd: dict,
                           base_dir: str | None = None) -> "_Registries":
        """Rebuild a worker-side scope from shipped maps."""
        regs = cls(estimators=ESTIMATORS.scope(),
                   topologies=TOPOLOGIES.scope(),
                   systems=default_registry().scope(),
                   base_dir=base_dir)
        for kind, c in est.items():
            regs.estimators.register(kind, c, replace=True)
        for kind, c in topo.items():
            regs.topologies.register(kind, c, replace=True)
        for sid, s in sysd.items():
            regs.systems.register(sid, s, source="<session>", replace=True)
        return regs

    def context(self, *, system_name: str = "",
                program=None) -> "BuildContext":
        return BuildContext(
            system_name=system_name, program=program,
            estimators=self.estimators, topologies=self.topologies,
            systems=self.systems, base_dir=self.base_dir)


#: stable error-row classification (satellite: error taxonomy).
#: ``plan``      — the workload's plan phase failed (parse/slice/build);
#: ``evaluate``  — the job's evaluate phase raised;
#: ``transport`` — the executor plumbing failed (a dead worker process),
#:                 not the job itself.
ERROR_TYPES = ("plan", "evaluate", "transport")


def _error_row(job: JobSpec, exc, error_type: str) -> dict:
    """An error result row: the grid point's axes plus a stable
    ``error_type`` (one of :data:`ERROR_TYPES`) and the exception class
    prefixed message."""
    row = dict(job.to_row())
    row["error"] = (exc if isinstance(exc, str)
                    else f"{type(exc).__name__}: {exc}")
    row["error_type"] = error_type
    return row


def _execute(job: JobSpec, plan: PredictionPlan, store,
             regs: _Registries | None = None) -> tuple[dict, dict]:
    """Evaluate one grid point against its shared plan; returns
    (result_row, freshly_computed_entries)."""
    t0 = time.perf_counter()
    if faults.active():
        faults.trip("evaluate", workload=job.workload, system=job.system,
                    estimator=job.estimator.kind)
    regs = regs or _Registries()
    system = build_system(job.system, registry=regs.systems)
    ctx = regs.context(system_name=job.system, program=plan.program)
    estimator = build_estimator(job.estimator, system,
                                registry=regs.estimators, context=ctx)
    topology = build_topology(job.topology, system,
                              registry=regs.topologies, context=ctx)
    pjob = PredictionJob(
        estimator=estimator, topology=topology,
        slicer=job.slicer, overlap=job.overlap,
        straggler_factor=job.straggler_factor, compression=job.compression,
        name=job.workload, system_name=system.name, cache_store=store,
        plan=plan)
    p = pjob.run()
    row = dict(job.to_row())
    row["fidelity"] = plan.fidelity  # the fidelity actually costed
    pred = p.to_row()
    row["toolchain"] = pred.pop("estimator")
    for k in ("workload", "system", "slicer"):
        pred.pop(k, None)
    row.update(pred)
    row.update(cost_columns(p.step_time_s, system, topology.num_devices))
    row["job_wall_s"] = time.perf_counter() - t0
    return row, dict(pjob.cached.new_entries)


def cost_columns(step_time_s: float, system, num_devices: int) -> dict:
    """TCO columns for one grid point, from the catalog's per-device
    cost/power ratings (absent fields -> absent columns, so unpriced
    systems produce exactly the pre-cost-model row shape).

    ``perf_per_usd`` is steps per dollar — the "how much work does a
    dollar buy" axis of the TCO survey, higher is better."""
    out: dict = {}
    if step_time_s <= 0:
        return out
    if system.cost_per_hour is not None:
        usd = step_time_s * num_devices * system.cost_per_hour / 3600.0
        out["usd_per_step"] = usd
        out["perf_per_usd"] = 1.0 / usd
    if system.tdp_watts is not None:
        out["joules_per_step"] = step_time_s * num_devices * system.tdp_watts
    return out


# process-pool worker state (plans + store, one set per worker process)
_WORKER: dict = {}


def _worker_init(plan_paths: dict, cache_entries: dict,
                 cache_path: str | None = None,
                 local_regs: tuple | None = None) -> None:
    """Per-worker setup.  ``plan_paths`` maps plan key -> pickled plan
    file; a worker unpickles a plan the first time one of its jobs
    references it (and never re-parses IR text).  With a ``cache_path``
    the worker opens the shared file-locked store — live view,
    write-through appends; without one it degrades to a private snapshot
    of the parent's entries.  ``local_regs`` carries a session's scoped
    registrations (plugin classes by reference, systems by value) so the
    worker resolves the same open vocabularies as the parent."""
    _WORKER["plan_paths"] = dict(plan_paths)
    _WORKER["plans"] = {}
    _WORKER["regs"] = (_Registries.from_local_entries(*local_regs)
                       if local_regs else _Registries())
    if cache_path:
        _WORKER["store"] = PersistentCache(cache_path)
    else:
        _WORKER["store"] = dict(cache_entries)


def _worker_plan(key: tuple) -> PredictionPlan:
    plan = _WORKER["plans"].get(key)
    if plan is None:
        plan = PlanStore.load_file(_WORKER["plan_paths"][key])
        _WORKER["plans"][key] = plan
    return plan


def _worker_run(job: JobSpec, plan_key: tuple,
                warm_entries: dict | None = None) -> tuple[dict, dict]:
    """Execute one job against this worker's plan + store; returns the
    result row plus the ``key -> (value, cost)`` entries it computed
    itself.  ``warm_entries`` carries a chain leader's fresh entries into
    snapshot-mode stores (path-backed stores see them via the log)."""
    store = _WORKER["store"]
    if warm_entries:
        if isinstance(store, PersistentCache):
            store.merge(warm_entries)
        else:
            store.update({k: v[0] if isinstance(v, (tuple, list)) else v
                          for k, v in warm_entries.items()})
    return _execute(job, _worker_plan(tuple(plan_key)), store,
                    _WORKER["regs"])


# ------------------------------ the campaign ------------------------------


@dataclass
class CampaignResult:
    """Everything a finished campaign produced: job_id-ordered result
    rows (error rows included), the summary dict, paths of any streamed
    artifacts, wall time, and the cache/plan reports."""
    name: str
    rows: list[dict]                 # job_id-ordered; error rows included
    summary: dict
    jsonl_path: str | None = None
    csv_path: str | None = None
    summary_path: str | None = None
    wall_s: float = 0.0
    cache: dict = field(default_factory=dict)
    plans: dict = field(default_factory=dict)
    resumed_rows: int = 0            # prior rows replayed, not re-run
    retried_rows: int = 0            # jobs that needed >= 1 retry

    @property
    def ok_rows(self) -> list[dict]:
        return [r for r in self.rows if "error" not in r]


#: row fields a resumed row must match against the expanded grid before
#: it is trusted (``fidelity`` is excluded on purpose: rows record the
#: fidelity actually costed, which may be a fallback from the spec's).
RESUME_MATCH_KEYS = ("workload", "system", "estimator", "slicer",
                     "topology", "overlap", "straggler_factor",
                     "compression")


def _match_resume_rows(jobs: list[JobSpec], resume_rows: list[dict]
                       ) -> tuple[dict[int, dict], dict]:
    """Partition a partial run's rows into trusted (replayed as-is) and
    everything that must re-run.

    A prior row is trusted only when its ``job_id`` exists in the
    expanded grid, it carries no ``error``, and its grid axes match the
    job exactly (a changed spec silently invalidates stale rows instead
    of smuggling them into the new grid).  Returns ``(job_id -> row,
    report)`` where the report counts resumed/stale rows and the error
    rows being retried, by ``error_type``."""
    expected = {j.job_id: j.to_row() for j in jobs}
    trusted: dict[int, dict] = {}
    report = {"resumed": 0, "rerun_errors": 0, "stale": 0, "missing": 0,
              "rerun_errors_by_type": {}}
    for r in resume_rows:
        jid = r.get("job_id")
        exp = expected.get(jid)
        if exp is None:
            report["stale"] += 1
            continue
        if "error" in r:
            et = r.get("error_type", "unknown")
            report["rerun_errors"] += 1
            report["rerun_errors_by_type"][et] = (
                report["rerun_errors_by_type"].get(et, 0) + 1)
            continue
        if any(r.get(k) != exp[k] for k in RESUME_MATCH_KEYS):
            report["stale"] += 1
            continue
        trusted[jid] = dict(r)
        trusted[jid]["resumed"] = True
    report["resumed"] = len(trusted)
    report["missing"] = (len(jobs) - len(trusted)
                         - report["rerun_errors"])
    return trusted, report


def _workload_texts(spec: CampaignSpec,
                    workloads: dict[str, Workload] | None,
                    only: set[str] | None = None) -> dict:
    """name -> {"raw": stablehlo, "optimized": hlo} for every grid workload.

    In-memory ``workloads`` take precedence; anything else is materialized
    from its spec (file read or jax export).  ``only`` restricts to the
    named workloads (a resumed campaign skips materializing — possibly
    re-exporting — workloads whose every row was replayed)."""
    provided = dict(workloads or {})
    texts: dict[str, dict] = {}
    for wspec in spec.workloads:
        if only is not None and wspec.name not in only:
            continue
        w = provided.get(wspec.name)
        if w is None:
            w = build_workload(wspec)
        texts[wspec.name] = {"raw": w.stablehlo_text,
                             "optimized": w.hlo_text}
    return texts


def _build_plans(jobs: list[JobSpec],
                 plans: PlanStore) -> tuple[dict, dict]:
    """The campaign's plan phase: build every referenced plan exactly
    once.  Returns (job_id -> plan key, plan key -> error string); jobs
    whose plan failed to build become error rows instead of running."""
    plan_keys: dict[int, tuple] = {}
    plan_errors: dict[tuple, str] = {}
    for job in jobs:
        key = plans.key_for(job)
        plan_keys[job.job_id] = key
        if key in plan_errors:
            continue
        try:
            plans.get(*key)
        except Exception as e:  # noqa: BLE001 — keep the campaign going
            plan_errors[key] = f"{type(e).__name__}: {e}"
    return plan_keys, plan_errors


def _schedule_chains(jobs: list[JobSpec], plan_keys: dict,
                     plans: PlanStore, schedule: str) -> list[list[JobSpec]]:
    """Order jobs into cache-affinity chains.

    ``locality``: one chain per cache group (see
    :meth:`JobSpec.cache_group` — jobs with identical (H, C, R) cache
    keysets).  The leader (first job) runs before its siblings are
    released, so a parallel executor cannot duplicate its cold misses;
    chains are ordered fingerprint-heavy-first (ties broken by job_id) so
    expensive plans warm the shared store before cheap ones.

    ``grid``: singleton chains in grid order — every job released at
    once, the pre-plan behavior.
    """
    if schedule == "grid":
        return [[j] for j in jobs]
    groups: dict[tuple, list[JobSpec]] = {}
    for job in jobs:
        # group by the exact cache keyset (fingerprint set, not plan
        # key): the linear and dep plans of a single-region workload
        # produce identical keys and must share a chain too
        groups.setdefault(
            job.cache_group(plans.fingerprint_set(plan_keys[job.job_id])),
            []).append(job)
    return sorted(
        groups.values(),
        key=lambda js: (-plans.weight(plan_keys[js[0].job_id]),
                        js[0].job_id))


def run_campaign(spec: CampaignSpec, *,
                 workloads: dict[str, Workload] | None = None,
                 out_dir: str | None = None,
                 executor: str = "serial",
                 max_workers: int | None = None,
                 cache_path: str | None = None,
                 cache: PersistentCache | None = None,
                 plan_store: PlanStore | None = None,
                 schedule: str = "locality",
                 progress: bool = False,
                 on_row=None,
                 session=None,
                 resume_rows: list[dict] | None = None,
                 retries: int = 0) -> CampaignResult:
    """Expand ``spec`` into jobs, plan, run them, and collect/stream
    results.

    ``workloads`` supplies in-memory :class:`Workload` objects by name
    (anything else is materialized from its spec — file read, jax
    export, or GEMM synthesis).  Every ``(workload, fidelity, slicer)``
    is parsed + sliced once into a shared plan; ``schedule`` orders the
    jobs over those plans (``locality`` default, ``grid`` legacy).
    ``cache_path`` points every job — and, under the process executor,
    every live worker — at one shared append-log (H, C, R) store; the
    log is compacted once on completion and the returned ``cache``
    report includes the across-run ``time_saving_fraction`` from
    persisted per-key costs.  ``session`` (a :class:`repro.api.Session`)
    supplies scoped registries — plugin estimator/topology kinds and
    user system catalogs — that jobs build against; without one the
    global registries and the spec's own ``system_catalog`` apply.

    Long-lived callers (``repro.serve``, a multi-campaign session) pass
    ``cache`` — an already-open :class:`PersistentCache`, in place of a
    fresh one built from ``cache_path`` — and ``plan_store`` — a warm
    :class:`PlanStore` whose parsed programs and plans carry over, so a
    repeated campaign re-parses nothing.  The returned cache/plan
    reports count only *this* run's activity (deltas against the warm
    store's counters); ``on_row(row)`` observes each result row as it
    completes (the serve daemon streams these to HTTP clients).

    Robustness knobs: ``resume_rows`` replays a partial prior run —
    trusted rows (see :func:`_match_resume_rows`) land in the output
    tagged ``"resumed": true`` without re-running (and without firing
    ``on_row``: stream consumers have seen them already), while error,
    stale, and missing rows re-run; the summary gains a ``resume``
    report saying exactly what was replayed vs retried.  ``retries``
    re-runs a job whose *evaluate* phase raised, up to N extra attempts
    (plan failures are deterministic and transport failures mean the
    executor itself died, so neither is retried)."""
    if executor not in EXECUTORS:
        raise ValueError(f"executor {executor!r} not in {EXECUTORS}")
    if schedule not in SCHEDULES:
        raise ValueError(f"schedule {schedule!r} not in {SCHEDULES}")
    t0 = time.perf_counter()
    spec.validate(provided=set(workloads or {}), session=session)
    regs = _Registries.for_session(session, spec)
    jobs = spec.expand()
    resumed: dict[int, dict] = {}
    resume_report: dict | None = None
    if resume_rows is not None:
        resumed, resume_report = _match_resume_rows(jobs, resume_rows)
        todo = [j for j in jobs if j.job_id not in resumed]
    else:
        todo = jobs
    texts = _workload_texts(
        spec, workloads,
        only={j.workload for j in todo} if resumed else None)

    if cache is None:
        cache = (PersistentCache(cache_path) if cache_path
                 else PersistentCache())
        loaded = cache.loaded_entries
    else:
        # a warm store: entries present now were "loaded" for this run
        cache_path = cache_path or cache.path
        loaded = len(cache)
    lock0 = cache.lock_roundtrips

    if plan_store is None:
        plans = PlanStore(texts)
    else:
        plans = plan_store
        plans.add_texts(texts)
    parse0, built0 = plans.parse_count, plans.plans_built
    plan_keys, plan_errors = _build_plans(todo, plans)

    jsonl_path = None
    jsonl_file = None
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        jsonl_path = os.path.join(out_dir, "results.jsonl")
        jsonl_file = open(jsonl_path, "w")
    jsonl_lock = threading.Lock()

    def emit_row(row: dict) -> None:
        if jsonl_file:
            with jsonl_lock:
                jsonl_file.write(json.dumps(row) + "\n")
                jsonl_file.flush()
        if on_row is not None:
            on_row(row)
        if faults.active():
            # fires *after* the row is flushed/streamed: a kill here
            # loses only rows not yet emitted, which is the guarantee
            # the chaos tests pin down
            faults.trip("campaign_row", job_id=row.get("job_id"),
                        workload=row.get("workload"))
        if progress:
            tag = (f"{row['step_time_s'] * 1e3:9.3f} ms"
                   if "step_time_s" in row else f"ERROR {row.get('error')}")
            print(f"  [{row['job_id']:4d}/{len(jobs)}] "
                  f"{row['workload']} × {row['system']} × "
                  f"{row['estimator']} × {row['slicer']}: {tag}",
                  flush=True)

    rows: list[dict] = []
    new_entry_count = 0
    retried_rows = 0
    try:
        # resumed rows replay straight into the artifacts (jsonl but not
        # on_row: a resuming stream consumer already holds them)
        for jid in sorted(resumed):
            rows.append(resumed[jid])
            if jsonl_file:
                with jsonl_lock:
                    jsonl_file.write(json.dumps(resumed[jid]) + "\n")
                    jsonl_file.flush()
        # jobs whose plan could not be built fail up front, as rows
        for job in todo:
            err = plan_errors.get(plan_keys[job.job_id])
            if err is not None:
                row = _error_row(job, err, "plan")
                rows.append(row)
                emit_row(row)
        runnable = [j for j in todo
                    if plan_keys[j.job_id] not in plan_errors]
        chains = _schedule_chains(runnable, plan_keys, plans, schedule)
        if executor == "process":
            prows, new_entry_count, retried_rows = _run_process_pool(
                chains, plan_keys, plans, cache, max_workers, emit_row,
                out_dir, regs, retries)
        else:
            prows, new_entry_count, retried_rows = _run_in_process(
                chains, plan_keys, plans, cache, emit_row,
                max_workers if executor == "thread" else 0, regs, retries)
        rows.extend(prows)
    finally:
        if jsonl_file:
            jsonl_file.close()

    rows.sort(key=lambda r: r["job_id"])
    if cache_path:
        cache.save(cache_path)

    # cache accounting covers this run's work only: a resumed row's
    # hit/miss counters describe the *previous* run's store traffic
    fresh = [r for r in rows if not r.get("resumed")]
    total_hits = sum(r.get("cache_hits", 0) for r in fresh)
    total_misses = sum(r.get("cache_misses", 0) for r in fresh)
    saved = sum(r.get("cache_saved_s", 0.0) for r in fresh)
    miss_cost = sum(r.get("cache_miss_cost_s", 0.0) for r in fresh)
    wall = time.perf_counter() - t0
    cache_report = {
        "path": cache_path,
        "loaded_entries": loaded,
        "total_entries": len(cache),
        "new_entries": new_entry_count,
        "hits": total_hits,
        "misses": total_misses,
        "hit_rate": total_hits / (total_hits + total_misses)
        if total_hits + total_misses else 0.0,
        # the paper's §III-B(c) metric, across-run thanks to persisted
        # per-key evaluation costs: fraction of estimator wall time that
        # hits avoided (hits on entries from previous runs count too)
        "saved_seconds": saved,
        "miss_cost_seconds": miss_cost,
        "time_saving_fraction": saved / (saved + miss_cost)
        if (saved + miss_cost) > 0 else 0.0,
        # parent-side flock acquisitions (load/refresh/append/compact)
        # during *this* run (a warm store keeps its lifetime counter)
        "lock_roundtrips": cache.lock_roundtrips - lock0,
    }
    plan_report = {
        "schedule": schedule,
        "jobs": len(jobs),
        "plan_keys": len({plan_keys[j.job_id] for j in todo}),
        # this run's parse/slice work only: zero on a warm plan store
        # that already holds every referenced plan
        "parse_calls": plans.parse_count - parse0,
        "plans_built": plans.plans_built - built0,
        "plan_errors": len(plan_errors),
    }
    summary = summarize(spec.name, rows)
    summary["wall_s"] = wall
    summary["cache"] = cache_report
    summary["plans"] = plan_report
    if resume_report is not None:
        summary["resume"] = resume_report
    if retries or retried_rows:
        summary["retries"] = {"configured": retries,
                              "rows_retried": retried_rows}
    # full spec provenance: a streamed results dir is self-describing,
    # so `report --results` (and humans) can recover the grid later
    summary["spec"] = spec.to_dict()

    csv_path = summary_path = None
    if out_dir:
        csv_path = os.path.join(out_dir, "results.csv")
        _write_csv(rows, csv_path)
        summary_path = os.path.join(out_dir, "summary.json")
        with open(summary_path, "w") as f:
            json.dump(summary, f, indent=2)

    return CampaignResult(
        name=spec.name, rows=rows, summary=summary, jsonl_path=jsonl_path,
        csv_path=csv_path, summary_path=summary_path, wall_s=wall,
        cache=cache_report, plans=plan_report,
        resumed_rows=len(resumed), retried_rows=retried_rows)


def _run_in_process(chains: list[list[JobSpec]], plan_keys: dict,
                    plans: PlanStore, cache: PersistentCache,
                    emit_row, thread_workers: int,
                    regs: _Registries | None = None,
                    retries: int = 0) -> tuple[list[dict], int, int]:
    """Serial or thread-pool execution over one shared live cache store.

    Thread mode submits each chain's leader first and releases the
    siblings only when it completes — by then every (H, C, R) key the
    siblings need is in the shared store, so they are pure hits."""
    new_keys: set[str] = set()
    rows: list[dict] = []
    rows_lock = threading.Lock()
    retried = [0]

    def run_one(job: JobSpec) -> None:
        for attempt in range(retries + 1):
            try:
                plan = plans.get(*plan_keys[job.job_id])
                row, new = _execute(job, plan, cache, regs)
                with rows_lock:
                    new_keys.update(new)
                break
            except Exception as e:  # noqa: BLE001 — keep the campaign going
                row = _error_row(job, e, "evaluate")
                if attempt == 0 and retries:
                    with rows_lock:
                        retried[0] += 1
        with rows_lock:
            rows.append(row)
        emit_row(row)

    if thread_workers == 0:
        for chain in chains:
            for job in chain:
                run_one(job)
    else:
        with ThreadPoolExecutor(max_workers=thread_workers) as pool:
            _drain_chains(pool, chains,
                          submit=lambda job, lead: pool.submit(run_one, job))
    return rows, len(new_keys), retried[0]


def _drain_chains(pool: Executor, chains: list[list[JobSpec]],
                  submit, on_done=None) -> None:
    """Leader-first chain draining: submit every chain's leader, release
    its siblings (concurrently, as singleton chains) when it completes.
    ``submit(job, leader_result)`` returns a future; ``on_done(chain,
    future)`` observes each completion and returns the value handed to
    the chain's siblings as ``leader_result``."""
    pending = {}
    for chain in chains:
        pending[submit(chain[0], None)] = chain
    while pending:
        done, _ = wait(pending, return_when=FIRST_COMPLETED)
        for fut in done:
            chain = pending.pop(fut)
            lead_result = on_done(chain, fut) if on_done else fut.result()
            for sib in chain[1:]:
                pending[submit(sib, lead_result)] = [sib]


def _run_process_pool(chains: list[list[JobSpec]], plan_keys: dict,
                      plans: PlanStore, cache: PersistentCache,
                      max_workers: int | None, emit_row,
                      out_dir: str | None,
                      regs: _Registries | None = None,
                      retries: int = 0) -> tuple[list[dict], int, int]:
    """Process-pool execution over pickled plan files.

    Workers never see workload text: the parent dumps each built plan to
    a file and ships only the (tiny) key -> path map at pool startup;
    every job submission carries its plan key.  With a path-backed cache
    the workers share the live append-log store (see
    :func:`_worker_init`); fresh entries are additionally merged into the
    parent for accounting.  Pathless caches fall back to snapshot-out /
    merge-in, with chain siblings warmed by their leader's fresh entries
    so they cannot duplicate its cold misses."""
    import multiprocessing
    import shutil
    import sys
    import tempfile
    from concurrent.futures.process import BrokenProcessPool

    # prefer spawn: the parent may hold live jax threads and fork of a
    # threaded process risks deadlock.  spawn re-imports __main__, which
    # only works when __main__ is a real file (CLI, pytest, scripts) —
    # fall back to fork for stdin/interactive parents.
    main_mod = sys.modules.get("__main__")
    method = ("spawn" if getattr(main_mod, "__file__", None)
              and os.path.exists(getattr(main_mod, "__file__"))
              else "fork")
    rows: list[dict] = []
    new_total = 0
    retried = 0
    # path-backed workers open the shared store themselves — don't ship
    # them a (potentially large) snapshot they would never read
    snapshot = {} if cache.path else dict(cache.entries)
    plan_dir = (os.path.join(out_dir, "plans") if out_dir
                else tempfile.mkdtemp(prefix="repro-plans-"))
    try:
        # ship only the plans this campaign references — a warm store
        # may hold plans from earlier campaigns these workers never run
        plan_paths = plans.dump(
            plan_dir, keys={plan_keys[j.job_id]
                            for chain in chains for j in chain})
        local_regs = (regs or _Registries()).local_entries()
        if not any(local_regs):
            local_regs = None     # nothing scoped: workers use globals
        with ProcessPoolExecutor(
                max_workers=max_workers, initializer=_worker_init,
                initargs=(plan_paths, snapshot, cache.path, local_regs),
                mp_context=multiprocessing.get_context(method)) as pool:

            def submit(job: JobSpec, lead_entries):
                # warm only snapshot-mode siblings: path-backed workers
                # already observe the leader's entries via the log
                warm = lead_entries if not cache.path else None
                try:
                    return pool.submit(_worker_run, job,
                                       plan_keys[job.job_id], warm)
                except BrokenProcessPool as e:
                    # dead pool: hand back a pre-failed future so the
                    # drain keeps going and every remaining job gets a
                    # transport error row instead of aborting the run
                    f = Future()
                    f.set_exception(e)
                    return f

            def on_done(chain, fut):
                nonlocal new_total, retried
                job = chain[0]
                new = {}
                for attempt in range(retries + 1):
                    try:
                        row, new = (fut.result() if attempt == 0
                                    else submit(job, None).result())
                        new_total += cache.merge(new)
                        break
                    except BrokenProcessPool as e:
                        # the pool itself died (a worker was SIGKILLed
                        # or crashed hard): every pending future fails
                        # the same way, and resubmitting can't help —
                        # record a transport row and let the campaign
                        # drain, leaving a resumable results.jsonl
                        row = _error_row(job, e, "transport")
                        break
                    except Exception as e:  # noqa: BLE001
                        # raised *inside* the worker and pickled back:
                        # an evaluate failure, retryable
                        row = _error_row(job, e, "evaluate")
                        if attempt == 0 and retries:
                            retried += 1
                rows.append(row)
                emit_row(row)
                return new

            _drain_chains(pool, chains, submit=submit, on_done=on_done)
    finally:
        if not out_dir:
            shutil.rmtree(plan_dir, ignore_errors=True)
    return rows, new_total, retried


def _write_csv(rows: list[dict], path: str) -> None:
    """Consolidate result rows into one CSV (union of all columns)."""
    fields: list[str] = []
    for r in rows:
        for k in r:
            if k not in fields:
                fields.append(k)
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=fields)
        w.writeheader()
        w.writerows(rows)


def load_jsonl(path: str) -> list[dict]:
    """Read back a streamed results file."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out
