"""Materialize estimators, topologies, and workloads from campaign specs.

Everything here turns a primitives-only spec into live pipeline objects,
which is what lets :class:`~repro.campaign.spec.JobSpec` records cross a
process boundary: the worker rebuilds the objects locally from the spec.

Estimator and topology kinds resolve through the open registries
(:mod:`repro.core.registry`): each backend class carries a
``from_spec(options, system, context)`` constructor, so adding a kind is
one decorated class — no edits here.  Systems resolve through the
catalog (:mod:`repro.core.catalog`).  Callers with session-scoped
backends pass their registries; the defaults are the globals.
"""
from __future__ import annotations

from ..core.catalog import SystemRegistry, default_registry
from ..core.estimators.base import ComputeEstimator
from ..core.ir.graph import Program
from ..core.network import Topology
from ..core.pipeline import Workload, export_workload
from ..core.registry import ESTIMATORS, TOPOLOGIES, BuildContext, Registry
from ..core.systems import System
from .spec import EstimatorSpec, TopologySpec, WorkloadSpec


def build_estimator(spec: EstimatorSpec, system: System, *,
                    system_name: str = "", program: Program | None = None,
                    registry: Registry | None = None,
                    context: BuildContext | None = None) -> ComputeEstimator:
    reg = registry or ESTIMATORS
    if spec.kind not in reg:
        raise ValueError(reg.unknown_message(spec.kind))
    if context is None:
        context = BuildContext(system_name=system_name, program=program,
                               estimators=reg)
    return reg.get(spec.kind).from_spec(spec.options_dict, system, context)


def build_topology(spec: TopologySpec, system: System, *,
                   registry: Registry | None = None,
                   context: BuildContext | None = None) -> Topology:
    reg = registry or TOPOLOGIES
    if spec.kind not in reg:
        raise ValueError(reg.unknown_message(spec.kind))
    if context is None:
        context = BuildContext(topologies=reg)
    return reg.get(spec.kind).from_spec(spec.params_dict, system, context)


def build_system(name: str,
                 registry: SystemRegistry | None = None) -> System:
    return (registry or default_registry()).get(name)


def build_workload(spec: WorkloadSpec) -> Workload:
    """Materialize a workload from its spec source: read pre-exported IR
    from disk, synthesize a GEMM, or export via jax (forward or full
    train step, per ``spec.mode``)."""
    if spec.stablehlo_path or spec.hlo_path:
        w = Workload(name=spec.name)
        if spec.stablehlo_path:
            with open(spec.stablehlo_path) as f:
                w.stablehlo_text = f.read()
        if spec.hlo_path:
            with open(spec.hlo_path) as f:
                w.hlo_text = f.read()
        return w
    if spec.gemm is not None:
        return _synthesize_gemm(spec)
    if spec.mode in ("prefill", "decode"):
        return _synthesize_serving(spec)
    return _export_from_arch(spec)


def _synthesize_gemm(spec: WorkloadSpec) -> Workload:
    """A single-``dot_general`` StableHLO workload, written directly as
    MLIR text (no jax needed) — the operator-level unit of the paper's
    Fig 10 GEMM sweeps.  The lone compute region it slices into carries
    exactly the (M, N, K, dtype) the systolic/roofline estimators cost."""
    g = spec.gemm
    m, n, k = int(g["m"]), int(g["n"]), int(g["k"])
    dt = str(g.get("dtype", "bf16"))
    lhs, rhs, out = f"{m}x{k}x{dt}", f"{k}x{n}x{dt}", f"{m}x{n}x{dt}"
    text = (
        "module @gemm {\n"
        f"  func.func public @main(%arg0: tensor<{lhs}>, "
        f"%arg1: tensor<{rhs}>) -> tensor<{out}> {{\n"
        f"    %0 = stablehlo.dot_general %arg0, %arg1, "
        f"contracting_dims = [1] x [0], "
        f"precision = [DEFAULT, DEFAULT] : "
        f"(tensor<{lhs}>, tensor<{rhs}>) -> tensor<{out}>\n"
        f"    return %0 : tensor<{out}>\n"
        "  }\n"
        "}\n")
    return Workload(name=spec.name, stablehlo_text=text,
                    meta={"gemm": {"m": m, "n": n, "k": k, "dtype": dt}})


def synthesize_gemm_stack(shapes: list[tuple[int, int, int]]) -> str:
    """A StableHLO module of independent ``dot_general``s separated by
    ``optimization_barrier``s — one compute region per GEMM under the
    linear slicer, written directly as MLIR text (no jax needed).

    The multi-region sibling of :func:`_synthesize_gemm`; benchmarks and
    tests use it to exercise plan reuse and batched cache traffic on
    workloads with many distinct fingerprints."""
    args, body = [], []
    v = 0
    for i, (m, n, k) in enumerate(shapes):
        lhs, rhs, out = f"{m}x{k}xbf16", f"{k}x{n}xbf16", f"{m}x{n}xbf16"
        args += [f"%arg{2 * i}: tensor<{lhs}>",
                 f"%arg{2 * i + 1}: tensor<{rhs}>"]
        body.append(
            f"    %{v} = stablehlo.dot_general %arg{2 * i}, "
            f"%arg{2 * i + 1}, contracting_dims = [1] x [0], "
            f"precision = [DEFAULT, DEFAULT] : "
            f"(tensor<{lhs}>, tensor<{rhs}>) -> tensor<{out}>")
        v += 1
        body.append(f"    %{v} = stablehlo.optimization_barrier "
                    f"%{v - 1} : tensor<{out}>")
        v += 1
    m, n, _ = shapes[-1]
    return ("module @gemm_stack {\n"
            f"  func.func public @main({', '.join(args)}) -> "
            f"tensor<{m}x{n}xbf16> {{\n" + "\n".join(body) +
            f"\n    return %{v - 1} : tensor<{m}x{n}xbf16>\n  }}\n}}\n")


def _while_wrap(body: str, trips: int, carry_in: str, carry_ty: str,
                indent: str, tag: str) -> str:
    """Wrap ``body`` in a ``stablehlo.while`` counting to ``trips``,
    printed exactly as ``jax.lax.fori_loop`` lowers (counter + one carried
    tensor, cond/do blocks).  ``tag`` keeps SSA names unique across
    nesting levels."""
    i = indent
    return (
        f"{i}%c{tag} = stablehlo.constant dense<0> : tensor<i32>\n"
        f"{i}%out{tag}:2 = stablehlo.while(%iterArg{tag} = %c{tag}, "
        f"%iterArg{tag}_0 = {carry_in}) : tensor<i32>, {carry_ty}\n"
        f"{i} cond {{\n"
        f"{i}  %limit{tag} = stablehlo.constant dense<{trips}> : tensor<i32>\n"
        f"{i}  %cmp{tag} = stablehlo.compare  LT, %iterArg{tag}, "
        f"%limit{tag},  SIGNED : (tensor<i32>, tensor<i32>) -> tensor<i1>\n"
        f"{i}  stablehlo.return %cmp{tag} : tensor<i1>\n"
        f"{i}}} do {{\n" + body + "\n"
        f"{i}  %one{tag} = stablehlo.constant dense<1> : tensor<i32>\n"
        f"{i}  %next{tag} = stablehlo.add %iterArg{tag}, %one{tag} "
        f": tensor<i32>\n"
        f"{i}  stablehlo.return %next{tag}, %iterArg{tag}_0 "
        f": tensor<i32>, {carry_ty}\n"
        f"{i}}}")


def synthesize_sharded_stack(shapes: list[tuple[int, int, int]],
                             groups: int = 8,
                             steps: int | None = None,
                             microbatches: int | None = None) -> str:
    """A data-parallel sharded training stack, written directly as MLIR
    text (no jax needed): per layer a ``custom_call @Sharding`` carrying a
    quoted ``mhlo.sharding`` annotation, a ``dot_general``, a bias ``add``,
    a multi-line ``all_reduce`` region op (gradient sync) with
    ``replica_groups``/``channel_handle``, and an ``optimization_barrier``.
    With ``steps``, the whole stack sits inside a ``stablehlo.while``
    accumulation loop (the shape ``jax.lax.fori_loop`` lowers to), cond/do
    blocks written exactly as ``jax.jit(...).lower()`` prints them; with
    ``microbatches`` too, that loop nests inside an outer
    gradient-accumulation loop — the two-level pipeline-schedule shape.

    Line shapes mirror ``jax.jit(shard_map(...)).lower().as_text()``
    exports verbatim — quoted attribute strings, collective region blocks,
    and loop bodies are exactly where the two front ends diverge most in
    cost, so benchmarks use this for the cold-parse comparison and the
    differential suite parses it through both."""
    ids = ", ".join(str(d) for d in range(groups))
    depth = (steps is not None) + (microbatches is not None)
    pad = "    " + "  " * depth
    args, body = [], []
    v = 0
    for i, (m, n, k) in enumerate(shapes):
        lhs, rhs, out = f"{m}x{k}xbf16", f"{k}x{n}xbf16", f"{m}x{n}xbf16"
        args += [f"%arg{2 * i}: tensor<{lhs}>",
                 f"%arg{2 * i + 1}: tensor<{rhs}>"]
        body.append(
            f'{pad}%{v} = stablehlo.custom_call @Sharding(%arg{2 * i + 1}) '
            f'{{backend_config = "", mhlo.sharding = '
            f'"{{devices=[{groups},1]<=[{groups}]}}"}} : '
            f"(tensor<{rhs}>) -> tensor<{rhs}>")
        v += 1
        body.append(
            f"{pad}%{v} = stablehlo.dot_general %arg{2 * i}, %{v - 1}, "
            f"contracting_dims = [1] x [0], precision = [DEFAULT, DEFAULT] "
            f": (tensor<{lhs}>, tensor<{rhs}>) -> tensor<{out}>")
        v += 1
        body.append(f"{pad}%{v} = stablehlo.add %{v - 1}, %{v - 1} : "
                    f"tensor<{out}>")
        v += 1
        body.append(
            f'{pad}%{v} = "stablehlo.all_reduce"(%{v - 1}) '
            f"<{{channel_handle = #stablehlo.channel_handle<handle = "
            f"{i + 1}, type = 1>, replica_groups = dense<[[{ids}]]> : "
            f"tensor<1x{groups}xi64>, use_global_device_ids}}> ({{\n"
            f"{pad}^bb0(%lhs{i}: tensor<bf16>, %rhs{i}: tensor<bf16>):\n"
            f"{pad}  %s{i} = stablehlo.add %lhs{i}, %rhs{i} : tensor<bf16>\n"
            f"{pad}  stablehlo.return %s{i} : tensor<bf16>\n"
            f"{pad}}}) : (tensor<{out}>) -> tensor<{out}>")
        v += 1
        body.append(f"{pad}%{v} = stablehlo.optimization_barrier "
                    f"%{v - 1} : tensor<{out}>")
        v += 1
    m, n, _ = shapes[-1]
    out = f"tensor<{m}x{n}xbf16>"
    if depth == 0:
        core = "\n".join(body) + f"\n    return %{v - 1} : {out}\n"
    else:
        m0, _, k0 = shapes[0]
        acc = f"tensor<{m0}x{k0}xbf16>"
        core = "\n".join(body)
        result = "%out"
        if steps is not None:
            indent = "      " if microbatches is not None else "    "
            carry = "%iterArg_mb_0" if microbatches is not None else "%arg0"
            core = _while_wrap(core, steps, carry, acc, indent, "")
        if microbatches is not None:
            core = _while_wrap(core, microbatches, "%arg0", acc, "    ",
                               "_mb")
            result = "%out_mb"
        core += f"\n    return {result}#1 : {acc}\n"
        out = acc
    return ("module @sharded_stack attributes "
            f"{{mhlo.num_partitions = {groups} : i32}} {{\n"
            f"  func.func public @main({', '.join(args)}) -> "
            f"{out} {{\n" + core + "  }\n}\n")


def serving_step_shapes(cfg, mode: str, batch: int,
                        seq: int) -> list[tuple[int, int, int]]:
    """The (m, n, k) GEMM shapes of one serving step of ``cfg``.

    First-order attention + MLP model of what ``serve/decode.py``
    executes, flattened so every term is a plain 2-D GEMM with the right
    total FLOPs and — critically for the decode regime — the right
    dominant memory traffic:

    * ``prefill``: the whole ``batch × seq`` prompt in one pass; the
      score/context GEMMs carry the O(seq²) attention term.
    * ``decode``: one new token per sequence against a ``seq``-deep KV
      cache.  The projection GEMMs have m = batch (weight-bound) and the
      attention GEMMs are flattened GEMVs whose operand footprint is the
      *full KV cache read* (m = batch·heads·seq, k = head_dim, n = 1),
      which is exactly what makes decode KV-cache-bound rather than
      compute-bound.

    Per layer: q/k/v projections, scores, context, output projection,
    MLP up + down; one LM head GEMM closes the step.  All layers share
    shapes, so the plan's regions collapse onto a handful of distinct
    fingerprints — a serving sweep is cache-friendly by construction.
    """
    d, h, hk = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    hd = cfg.head_dim or d // h
    ff, vocab = cfg.d_ff, cfg.vocab_size
    if mode == "prefill":
        t = batch * seq                       # prompt tokens in flight
        layer = [
            (t, h * hd, d), (t, hk * hd, d), (t, hk * hd, d),  # q, k, v
            (batch * h * seq, seq, hd),       # scores  QK^T (O(seq^2))
            (batch * h * seq, hd, seq),       # context scores·V
            (t, d, h * hd),                   # output projection
            (t, ff, d), (t, d, ff),           # MLP up, down
        ]
    else:
        layer = [
            (batch, h * hd, d), (batch, hk * hd, d), (batch, hk * hd, d),
            (batch * h * seq, 1, hd),         # scores: full K-cache read
            (batch * h * hd, 1, seq),         # context: full V-cache read
            (batch, d, h * hd),
            (batch, ff, d), (batch, d, ff),
        ]
    shapes = [s for _ in range(cfg.num_layers) for s in layer]
    shapes.append((batch, vocab, d))          # LM head (last position)
    return shapes


def _synthesize_serving(spec: WorkloadSpec) -> Workload:
    """A jax-free serving-step workload (``mode="prefill"``/``"decode"``)
    synthesized from the arch's registered :class:`ModelConfig` — the
    campaign-grid promotion of ``serve/decode.py``'s execution shape.
    Pure MLIR text via :func:`synthesize_gemm_stack`, so serving sweeps
    (and the what-if search built on them) run without jax."""
    import importlib

    mod_name = spec.arch.replace("-", "_").replace(".", "_")
    try:
        cfg = importlib.import_module(f"repro.configs.{mod_name}").CONFIG
    except ImportError:
        from ..models import ARCH_IDS, EXTRA_IDS
        raise ValueError(
            f"workload {spec.name!r}: unknown arch {spec.arch!r} for "
            f"mode {spec.mode!r}; have {sorted(ARCH_IDS + EXTRA_IDS)}"
        ) from None
    if cfg.num_heads <= 0 or cfg.family == "ssm":
        raise ValueError(
            f"workload {spec.name!r}: mode {spec.mode!r} models an "
            f"attention KV cache; arch {spec.arch!r} ({cfg.family}) "
            "has none")
    shapes = serving_step_shapes(cfg, spec.mode, spec.batch, spec.seq)
    return Workload(
        name=spec.name,
        stablehlo_text=synthesize_gemm_stack(shapes),
        meta={"serving": {"arch": spec.arch, "mode": spec.mode,
                          "batch": spec.batch, "seq": spec.seq,
                          "num_layers": cfg.num_layers}})


def _mesh_for(spec: WorkloadSpec):
    """Build the spec's device mesh (None when the spec has none)."""
    if spec.mesh is None:
        return None
    import jax

    from ..launch.mesh import make_mesh

    shape = tuple(spec.mesh)
    need = 1
    for s in shape:
        need *= s
    have = len(jax.devices())
    if need > have:
        raise ValueError(
            f"workload {spec.name!r}: mesh {shape} needs {need} devices "
            f"but only {have} are visible — set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={need} before jax "
            "starts (the repro.campaign CLI does this automatically)")
    axes = ("data", "model") if len(shape) == 2 else ("pod", "data", "model")
    return make_mesh(shape, axes)


def _export_from_arch(spec: WorkloadSpec) -> Workload:
    """Export a workload from a registered model config via jax.

    ``mode="forward"`` lowers one forward pass; ``mode="train"`` lowers a
    full train step (loss + grad + optimizer update) with abstract
    optimizer state, sharded over the spec's mesh — the export paths are
    shared with the fig benchmarks (``repro.train.loop.train_step_exports``
    / ``repro.models.resnet.resnet_train_exports``), so campaign numbers
    are bit-identical to the hand-rolled sweeps they replaced."""
    import contextlib

    import jax

    mesh = _mesh_for(spec)
    ctx = mesh if mesh is not None else contextlib.nullcontext()

    if spec.arch.startswith("resnet"):
        from ..models.resnet import resnet_arch_config, resnet_train_exports
        from ..train.optimizer import OptimizerConfig

        if spec.mode != "train":
            raise ValueError(
                f"workload {spec.name!r}: resnet export is train-only "
                "(the fig7 workload family); set mode='train'")
        cfg = resnet_arch_config(spec.arch)
        jitted, abs_args = resnet_train_exports(
            cfg, spec.batch, spec.img, mesh,
            opt_cfg=OptimizerConfig(name=spec.optimizer))
        with ctx:
            return export_workload(jitted, *abs_args, name=spec.name)

    from ..models import get_config

    cfg = get_config(spec.arch)
    if spec.mode == "train":
        from ..train.loop import train_step_exports
        from ..train.optimizer import OptimizerConfig

        jitted, abs_args = train_step_exports(
            cfg, spec.seq, spec.batch, mesh,
            opt_cfg=OptimizerConfig(name=spec.optimizer))
        with ctx:
            return export_workload(jitted, *abs_args, name=spec.name)

    from ..configs.base import ShapeConfig
    from ..distributed.sharding import ShardingRules
    from ..models import input_specs, model_specs
    from ..models.params import abstract_params
    from ..models.transformer import forward

    shape = ShapeConfig(spec.name, spec.seq, spec.batch, "train")
    rules = ShardingRules() if mesh is not None else None
    params_abs = abstract_params(model_specs(cfg), mesh, rules)
    batch_abs = input_specs(cfg, shape, mesh, rules)
    with ctx:
        return export_workload(jax.jit(lambda p, b: forward(cfg, p, b)),
                               params_abs, batch_abs, name=spec.name)
