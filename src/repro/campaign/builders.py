"""Materialize estimators, topologies, and workloads from campaign specs.

Everything here turns a primitives-only spec into live pipeline objects,
which is what lets :class:`~repro.campaign.spec.JobSpec` records cross a
process boundary: the worker rebuilds the objects locally from the spec.
"""
from __future__ import annotations

from ..core.estimators import (MixedEstimator, ProfilingEstimator,
                               RooflineEstimator, SystolicEstimator)
from ..core.estimators.base import ComputeEstimator
from ..core.network import AllToAllNode, Dragonfly, MultiPod, Topology, Torus
from ..core.pipeline import Workload, export_workload
from ..core.systems import System, get_system
from ..core.ir.graph import Program
from .spec import EstimatorSpec, TopologySpec, WorkloadSpec

ESTIMATOR_KINDS = ("roofline", "systolic", "mixed", "profiling")
TOPOLOGY_KINDS = ("auto", "a2a", "dragonfly", "torus", "multipod")


def build_estimator(spec: EstimatorSpec, system: System, *,
                    system_name: str = "", program: Program | None = None
                    ) -> ComputeEstimator:
    opts = spec.options_dict
    if spec.kind == "roofline":
        return RooflineEstimator(
            system, mode=opts.get("mode", "region"),
            include_overheads=bool(opts.get("include_overheads", False)))
    if spec.kind == "systolic":
        return SystolicEstimator(system, opts.get("preset", "cocossim"))
    if spec.kind == "mixed":
        return MixedEstimator(
            SystolicEstimator(system, opts.get("preset", "cocossim")),
            RooflineEstimator(system))
    if spec.kind == "profiling":
        target = None if system_name == "host" else system
        return ProfilingEstimator(program=program,
                                  runs=int(opts.get("runs", 3)),
                                  target_system=target)
    raise ValueError(
        f"unknown estimator kind {spec.kind!r}; have {ESTIMATOR_KINDS}")


def build_topology(spec: TopologySpec, system: System) -> Topology:
    p = spec.params_dict
    kind = spec.kind
    if kind == "auto":
        # derive the family from the system's interconnect record — the
        # cross-architecture axis: one grid, per-system native fabric.
        # Only num_devices/link_bw come from the system so the numbers
        # match a hand-built AllToAllNode/Torus with class defaults.
        ic = system.interconnect
        n = int(p.get("num_devices", 4))
        if ic.kind in ("torus2d", "torus3d"):
            dims = tuple(ic.params.get("dims", (2, 2)))
            return Torus(dims=dims, link_bw=ic.link_bw)
        return AllToAllNode(num_devices=n, link_bw=ic.link_bw)
    if kind == "a2a":
        return AllToAllNode(**p)
    if kind == "dragonfly":
        return Dragonfly(**p)
    if kind == "torus":
        if "dims" in p:
            p = dict(p, dims=tuple(p["dims"]))
        return Torus(**p)
    if kind == "multipod":
        p = dict(p)
        pod = p.pop("pod", None)
        if pod is not None:
            pod = dict(pod)
            if "dims" in pod:
                pod["dims"] = tuple(pod["dims"])
            p["pod"] = Torus(**pod)
        return MultiPod(**p)
    raise ValueError(
        f"unknown topology kind {kind!r}; have {TOPOLOGY_KINDS}")


def build_system(name: str) -> System:
    return get_system(name)


def build_workload(spec: WorkloadSpec) -> Workload:
    """Materialize a workload: read pre-exported IR or export via jax."""
    if spec.stablehlo_path or spec.hlo_path:
        w = Workload(name=spec.name)
        if spec.stablehlo_path:
            with open(spec.stablehlo_path) as f:
                w.stablehlo_text = f.read()
        if spec.hlo_path:
            with open(spec.hlo_path) as f:
                w.hlo_text = f.read()
        return w
    return _export_from_arch(spec)


def _export_from_arch(spec: WorkloadSpec) -> Workload:
    import jax

    from ..configs.base import ShapeConfig
    from ..models import get_config, input_specs, model_specs
    from ..models.params import abstract_params
    from ..models.transformer import forward

    cfg = get_config(spec.arch)
    if spec.mode != "forward":
        raise ValueError(
            f"workload {spec.name!r}: CLI export supports mode='forward'; "
            "for train steps pass pre-exported IR via stablehlo_path/"
            "hlo_path or supply Workload objects through the API")
    shape = ShapeConfig(spec.name, spec.seq, spec.batch, "train")
    params_abs = abstract_params(model_specs(cfg))
    batch_abs = input_specs(cfg, shape)
    return export_workload(jax.jit(lambda p, b: forward(cfg, p, b)),
                           params_abs, batch_abs, name=spec.name)
