"""CLI entry point: ``python -m repro.campaign [run|validate] spec.json``.

A spec file is either one campaign — the JSON form of
:class:`~repro.campaign.spec.CampaignSpec` (see ``docs/campaign.md`` for
the full field reference) — or a *suite* that sequences several::

    {"name": "paper", "suite": ["fig7_resnet.json", "fig10_gemm.json"]}

Suite entries are paths relative to the suite file (or inline campaign
dicts); sub-campaigns run sequentially, sharing one persistent (H, C, R)
cache and writing results under ``<out>/<campaign-name>/``.  This is what
makes ``python -m repro.campaign run specs/paper_full.json`` a
single-command full-paper reproduction.

``validate`` checks every spec (grid axes, workload sources, mesh shapes)
and prints the expanded grid size without running anything — CI runs it
on the checked-in ``specs/*.json``.

Arch workloads with a ``mesh`` need that many XLA devices; the CLI counts
the devices the specs need and presets
``--xla_force_host_platform_device_count`` *before* jax initializes.

Minimal single-campaign example::

    {
      "name": "gpu-sweep",
      "workloads": [{"name": "llama3-100m", "arch": "llama3-100m",
                     "mode": "train", "mesh": [4, 1],
                     "seq": 256, "batch": 4}],
      "systems": ["a100", "h100", "b200"],
      "estimators": [{"kind": "roofline"},
                     {"kind": "roofline", "fidelity": "raw",
                      "options": {"mode": "per-op",
                                  "include_overheads": true}}],
      "slicers": ["linear", "dep"]
    }
"""
from __future__ import annotations

import argparse
import json
import os
import sys

# only spec.py (pure stdlib) at module load: `validate` must work in an
# environment without jax/numpy installed (the CI docs job); the runner
# and its estimator imports load lazily in the `run` branch
from .spec import CampaignSpec


def load_specs(path: str) -> list[tuple[str, CampaignSpec]]:
    """Load a spec file into ``[(campaign_name, CampaignSpec), ...]``.

    A plain campaign yields one entry; a suite file yields one per
    sub-campaign (path entries resolved relative to the suite file).
    """
    with open(path) as f:
        raw = json.load(f)
    if "suite" not in raw:
        spec = CampaignSpec.from_dict(raw)
        return [(spec.name, spec)]
    base = os.path.dirname(os.path.abspath(path))
    out: list[tuple[str, CampaignSpec]] = []
    for entry in raw["suite"]:
        if isinstance(entry, str):
            sub = os.path.join(base, entry)
            with open(sub) as f:
                spec = CampaignSpec.from_dict(json.load(f))
        else:
            spec = CampaignSpec.from_dict(entry)
        if any(spec.name == n for n, _ in out):
            # names key per-campaign output dirs — a duplicate would
            # silently clobber the earlier campaign's results
            raise ValueError(
                f"suite {path!r}: duplicate campaign name {spec.name!r}")
        out.append((spec.name, spec))
    return out


def _devices_needed(specs: list[tuple[str, CampaignSpec]]) -> int:
    need = 1
    for _, spec in specs:
        for w in spec.workloads:
            if w.mesh:
                n = 1
                for s in w.mesh:
                    n *= s
                need = max(need, n)
    return need


def _preset_device_count(specs: list[tuple[str, CampaignSpec]]) -> None:
    """Give the host XLA platform enough devices for every spec mesh.

    Only effective before jax initializes, and only when the user hasn't
    set XLA_FLAGS themselves."""
    need = _devices_needed(specs)
    if need <= 1:
        return
    if "jax" in sys.modules:
        return  # too late to change the platform; builders will verify
    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={need}")


def _print_grid(name: str, spec: CampaignSpec) -> None:
    jobs = spec.expand()
    print(f"campaign {name!r}: {len(jobs)} grid points "
          f"({len(spec.workloads)} workloads × {len(spec.systems)} systems "
          f"× {len(spec.estimators)} estimators × {len(spec.slicers)} "
          f"slicers × {len(spec.topologies)} topologies)", flush=True)


def main(argv: list[str] | None = None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    command = "run"
    if argv and argv[0] in ("run", "validate"):
        command = argv.pop(0)

    ap = argparse.ArgumentParser(
        prog="python -m repro.campaign",
        description="Run or validate a prediction campaign from a JSON "
                    "grid spec (single campaign or suite).")
    ap.add_argument("spec", nargs="+" if command == "validate" else None,
                    help="path to the campaign/suite spec (JSON)")
    if command == "run":
        ap.add_argument("--out", default="artifacts/campaign",
                        help="output directory for results.jsonl/csv + "
                             "summary.json (default: artifacts/campaign)")
        ap.add_argument("--executor", default="thread",
                        choices=("serial", "thread", "process"),
                        help="job executor (default: thread)")
        ap.add_argument("--jobs", type=int, default=None,
                        help="max parallel workers (default: executor's "
                             "choice)")
        ap.add_argument("--schedule", default="locality",
                        choices=("locality", "grid"),
                        help="job ordering: 'locality' groups jobs by "
                             "shared plan/cache keyset (leader first, "
                             "fingerprint-heavy plans warm the cache "
                             "early); 'grid' is pure grid order "
                             "(default: locality)")
        ap.add_argument("--cache", default=None, metavar="PATH",
                        help="persistent (H,C,R) cache file shared across "
                             "runs and live workers")
        ap.add_argument("--dry-run", action="store_true",
                        help="print the expanded grid and exit")
        ap.add_argument("--quiet", action="store_true",
                        help="suppress per-job progress lines")
    args = ap.parse_args(argv)

    if command == "validate":
        bad = 0
        for path in args.spec:
            try:
                specs = load_specs(path)
                for name, spec in specs:
                    spec.validate()
                    _print_grid(name, spec)
            except (OSError, ValueError, KeyError, TypeError,
                    json.JSONDecodeError) as e:
                print(f"INVALID {path}: {type(e).__name__}: {e}")
                bad += 1
                continue
            print(f"ok {path}")
        return 1 if bad else 0

    from .runner import run_campaign
    from .summary import format_table

    specs = load_specs(args.spec)
    _preset_device_count(specs)
    multi = len(specs) > 1
    failed = 0
    for name, spec in specs:
        _print_grid(name, spec)
        if args.dry_run:
            for j in spec.expand():
                r = j.to_row()
                print("  " + " × ".join(str(r[k]) for k in
                                        ("workload", "fidelity", "system",
                                         "estimator", "slicer", "topology")))
            continue
        out_dir = os.path.join(args.out, name) if multi else args.out
        result = run_campaign(
            spec, out_dir=out_dir, executor=args.executor,
            max_workers=args.jobs, cache_path=args.cache,
            schedule=args.schedule, progress=not args.quiet)
        print(format_table(result.summary))
        if result.csv_path:
            print(f"  wrote {result.jsonl_path}, {result.csv_path}, "
                  f"{result.summary_path}")
        failed += result.summary["num_failed"]
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
