"""CLI entry point: ``python -m repro.campaign spec.json [options]``.

The spec file is the JSON form of :class:`~repro.campaign.spec.CampaignSpec`
(see that module and ``examples/campaign_sweep.py``).  Minimal example::

    {
      "name": "gpu-sweep",
      "workloads": [{"name": "llama3-100m", "arch": "llama3-100m",
                     "seq": 256, "batch": 2}],
      "systems": ["a100", "h100", "b200"],
      "estimators": [{"kind": "roofline"},
                     {"kind": "roofline", "fidelity": "raw",
                      "options": {"mode": "per-op",
                                  "include_overheads": true}}],
      "slicers": ["linear", "dep"]
    }
"""
from __future__ import annotations

import argparse
import sys

from .runner import run_campaign
from .spec import CampaignSpec
from .summary import format_table


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.campaign",
        description="Run a prediction campaign from a JSON grid spec.")
    ap.add_argument("spec", help="path to the campaign spec (JSON)")
    ap.add_argument("--out", default="artifacts/campaign",
                    help="output directory for results.jsonl/csv + "
                         "summary.json (default: artifacts/campaign)")
    ap.add_argument("--executor", default="thread",
                    choices=("serial", "thread", "process"),
                    help="job executor (default: thread)")
    ap.add_argument("--jobs", type=int, default=None,
                    help="max parallel workers (default: executor's choice)")
    ap.add_argument("--cache", default=None, metavar="PATH",
                    help="persistent (H,C,R) cache file shared across runs")
    ap.add_argument("--dry-run", action="store_true",
                    help="print the expanded grid and exit")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress per-job progress lines")
    args = ap.parse_args(argv)

    spec = CampaignSpec.from_json(args.spec)
    jobs = spec.expand()
    print(f"campaign {spec.name!r}: {len(jobs)} grid points "
          f"({len(spec.workloads)} workloads × {len(spec.systems)} systems "
          f"× {len(spec.estimators)} estimators × {len(spec.slicers)} "
          f"slicers × {len(spec.topologies)} topologies)", flush=True)
    if args.dry_run:
        for j in jobs:
            r = j.to_row()
            print("  " + " × ".join(str(r[k]) for k in
                                    ("workload", "fidelity", "system",
                                     "estimator", "slicer", "topology")))
        return 0

    result = run_campaign(
        spec, out_dir=args.out, executor=args.executor,
        max_workers=args.jobs, cache_path=args.cache,
        progress=not args.quiet)
    print(format_table(result.summary))
    if result.csv_path:
        print(f"  wrote {result.jsonl_path}, {result.csv_path}, "
              f"{result.summary_path}")
    return 1 if result.summary["num_failed"] else 0


if __name__ == "__main__":
    sys.exit(main())
