"""CLI entry point: ``python -m repro.campaign [run|validate|report|list]``.

A spec file is either one campaign — the JSON form of
:class:`~repro.campaign.spec.CampaignSpec` (see ``docs/campaign.md`` for
the full field reference) — or a *suite* that sequences several::

    {"name": "paper", "suite": ["fig7_resnet.json", "fig10_gemm.json"]}

Suite entries are paths relative to the suite file (or inline campaign
dicts); sub-campaigns run sequentially, sharing one persistent (H, C, R)
cache and writing results under ``<out>/<campaign-name>/``.  This is what
makes ``python -m repro.campaign run specs/paper_full.json`` a
single-command full-paper reproduction.

``validate`` checks every spec (grid axes, zip groups, workload sources,
mesh shapes) and prints the expanded grid size without running anything —
CI runs it on the checked-in ``specs/*.json``.

``list`` prints the live extension vocabularies — registered estimator
kinds, topology kinds, and the system catalog with each entry's source
file — so the open vocabularies stay discoverable; ``--check``
additionally validates every catalog record against the schema (CI runs
``list --check`` over the shipped ``specs/systems/`` in the docs job).
``--systems PATH`` (file or directory of system JSON records, repeatable,
all subcommands) overlays user catalogs; campaign specs can do the same
with a ``system_catalog`` field.

``report`` turns campaign results into the paper's evaluation artifacts
(MAPE vs recorded references, Kendall-τ/Spearman rank preservation,
fidelity tables — ``repro.campaign.report``), emitted as JSON + markdown.
``--check`` additionally gates the predictions against the checked-in
golden snapshots (``specs/golden/``), failing on drift beyond tolerance
or any rank inversion; ``--update-golden`` regenerates the snapshots and
reference rows after an intentional change.  CI runs ``report --check``
on every checked-in spec grid.

Arch workloads with a ``mesh`` need that many XLA devices; the CLI counts
the devices the specs need and presets
``--xla_force_host_platform_device_count`` *before* jax initializes.

Minimal single-campaign example::

    {
      "name": "gpu-sweep",
      "workloads": [{"name": "llama3-100m", "arch": "llama3-100m",
                     "mode": "train", "mesh": [4, 1],
                     "seq": 256, "batch": 4}],
      "systems": ["a100", "h100", "b200"],
      "estimators": [{"kind": "roofline"},
                     {"kind": "roofline", "fidelity": "raw",
                      "options": {"mode": "per-op",
                                  "include_overheads": true}}],
      "slicers": ["linear", "dep"]
    }
"""
from __future__ import annotations

import argparse
import json
import os
import sys

# only spec.py + the api facade (pure stdlib) at module load: `validate`
# and `list` must work in an environment without jax/numpy installed
# (the CI docs job); the runner and its estimator imports load lazily in
# the `run` branch
from .spec import CampaignSpec


def load_specs(path: str,
               session=None) -> list[tuple[str, CampaignSpec]]:
    """Load a spec file into ``[(campaign_name, CampaignSpec), ...]``.

    A plain campaign yields one entry; a suite file yields one per
    sub-campaign (path entries resolved relative to the suite file).
    ``session`` scopes spec validation to its registries/catalogs.
    """
    with open(path) as f:
        raw = json.load(f)
    if "suite" not in raw:
        spec = CampaignSpec.from_file_dict(raw, path, session=session)
        return [(spec.name, spec)]
    base = os.path.dirname(os.path.abspath(path))
    out: list[tuple[str, CampaignSpec]] = []
    for entry in raw["suite"]:
        if isinstance(entry, str):
            sub = os.path.join(base, entry)
            spec = CampaignSpec.from_json(sub, session=session)
        else:
            spec = CampaignSpec.from_dict(entry, session=session)
        if any(spec.name == n for n, _ in out):
            # names key per-campaign output dirs — a duplicate would
            # silently clobber the earlier campaign's results
            raise ValueError(
                f"suite {path!r}: duplicate campaign name {spec.name!r}")
        out.append((spec.name, spec))
    return out


def _devices_needed(specs: list[tuple[str, CampaignSpec]]) -> int:
    need = 1
    for _, spec in specs:
        for w in spec.workloads:
            if w.mesh:
                n = 1
                for s in w.mesh:
                    n *= s
                need = max(need, n)
    return need


def _preset_device_count(specs: list[tuple[str, CampaignSpec]]) -> None:
    """Give the host XLA platform enough devices for every spec mesh.

    Only effective before jax initializes, and only when the user hasn't
    set XLA_FLAGS themselves."""
    need = _devices_needed(specs)
    if need <= 1:
        return
    if "jax" in sys.modules:
        return  # too late to change the platform; builders will verify
    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={need}")


def _print_grid(name: str, spec: CampaignSpec) -> None:
    jobs = spec.expand()
    zipped = {a: tuple(g) for g in spec.zip_axes for a in g}
    shown, bits = set(), []
    for axis in ("workloads", "systems", "estimators", "slicers",
                 "topologies"):
        if axis in shown:
            continue
        group = zipped.get(axis)
        if group is None:
            bits.append(f"{len(getattr(spec, axis))} {axis}")
        else:
            shown.update(group)
            bits.append(f"{len(getattr(spec, axis))} zipped "
                        + "⊗".join(group))
    print(f"campaign {name!r}: {len(jobs)} grid points "
          f"({' × '.join(bits)})", flush=True)


def _load_results_jsonl(path: str) -> list[dict]:
    """Read back a streamed results file (stdlib twin of
    ``runner.load_jsonl`` — reporting on existing results must not pull
    in the estimator stack)."""
    rows = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                rows.append(json.loads(line))
    return rows


def _report_command(args, session=None) -> int:
    """The ``report`` subcommand: build evaluation reports (and golden
    checks/updates) for every campaign named by the spec arguments."""
    from .report import (DEFAULT_TOLERANCE, build_report, check_rows,
                         golden_path, load_json, make_golden,
                         make_reference, reference_path, render_markdown,
                         write_json)

    entries = []  # (spec_file_path, campaign_name, CampaignSpec)
    for path in args.spec:
        for name, spec in load_specs(path, session=session):
            if any(name == n for _, n, _ in entries):
                raise ValueError(
                    f"report: duplicate campaign name {name!r} across "
                    "spec arguments")
            entries.append((path, name, spec))
    if args.results and len(entries) != 1:
        print("report: --results requires exactly one campaign")
        return 2

    failures: list[str] = []
    num_failed = 0
    if not args.results:
        _preset_device_count([(n, s) for _, n, s in entries])
    for path, name, spec in entries:
        out_dir = os.path.join(args.out, name)
        if args.results:
            rows = _load_results_jsonl(args.results)
        else:
            from .runner import run_campaign

            _print_grid(name, spec)
            result = run_campaign(
                spec, out_dir=out_dir, executor=args.executor,
                max_workers=args.jobs, cache_path=args.cache,
                progress=not args.quiet, session=session)
            rows = result.rows

        reference = load_json(reference_path(path, name))
        if args.update_golden:
            tol = (args.tolerance if args.tolerance is not None
                   else DEFAULT_TOLERANCE)
            gpath = write_json(
                golden_path(path, name),
                make_golden(name, rows, tolerance=tol,
                            meta={"spec": os.path.basename(path)}))
            # references are recorded evaluation *baselines*, not
            # regression snapshots: only seed a missing file (delete it
            # first to deliberately re-record).  Seeding happens before
            # build_report so the very first --update-golden run already
            # reports MAPE against the freshly recorded rows.
            rpath = reference_path(path, name)
            if reference is None:
                reference = make_reference(name, rows)
                write_json(rpath, reference)
                print(f"  wrote {gpath}, {rpath}")
            else:
                print(f"  wrote {gpath} (kept existing {rpath})")

        report = build_report(name, rows, reference=reference)
        num_failed += report["num_failed"]
        if args.check:
            golden = load_json(golden_path(path, name))
            if golden is None:
                check = {"failures": [
                    f"{name}: no golden snapshot at "
                    f"{golden_path(path, name)} — generate one with "
                    "--update-golden"], "rows_checked": 0,
                    "tolerance": (args.tolerance
                                  if args.tolerance is not None
                                  else DEFAULT_TOLERANCE)}
            else:
                check = check_rows(golden, rows,
                                   tolerance=args.tolerance)
            report["golden_check"] = check
            failures.extend(check["failures"])

        jpath = write_json(os.path.join(out_dir, "report.json"), report)
        mpath = os.path.join(out_dir, "report.md")
        with open(mpath, "w") as f:
            f.write(render_markdown(report))
        rp = report["rank_preservation"]
        trend = ("n/a" if rp["min_kendall_tau"] is None else
                 f"min τ {rp['min_kendall_tau']}")
        check_tag = ""
        if "golden_check" in report:
            gc = report["golden_check"]
            n_fail = len(gc["failures"])
            drift = gc.get("max_drift")
            drift_tag = ("" if drift is None
                         else f", max drift {drift:.1e}")
            check_tag = (f" · golden OK{drift_tag}" if not n_fail
                         else f" · golden FAILED ({n_fail})")
        print(f"report {name!r}: {report['num_ok']}/{report['num_rows']} "
              f"rows · {trend}{check_tag}")
        print(f"  wrote {jpath}, {mpath}")

    for f in failures:
        print(f"GOLDEN-CHECK FAILURE: {f}")
    if num_failed:
        # mirror `run`: a half-failed campaign must not exit 0 just
        # because its surviving rows produced a report
        print(f"report: {num_failed} grid points failed")
    return 1 if failures or num_failed else 0


def _list_command(args) -> int:
    """The ``list`` subcommand: print (and with ``--check`` validate)
    the live extension vocabularies."""
    from .. import api
    from ..core.catalog import validate_system_dict

    failures: list[str] = []
    try:
        session = api.Session(systems=args.systems or ())
    except (OSError, ValueError, TypeError) as e:
        print(f"INVALID catalog: {e}")
        return 1
    info = session.describe()
    print("estimator kinds: " + ", ".join(info["estimators"]))
    print("topology kinds:  " + ", ".join(info["topologies"]))
    print(f"systems ({len(info['systems'])} catalog entries + 'host'):")
    width = max((len(s["id"]) for s in info["systems"]), default=0)
    for s in info["systems"]:
        print(f"  {s['id']:<{width}}  {s['name']:<18} {s['source']}")
    if args.check:
        # re-validate every catalog *file* against the schema, with
        # per-file errors: the shipped specs/systems/ dir plus any
        # --systems paths (CI's docs job runs this)
        from ..core.catalog import _DEFAULT_DIR
        files: list[str] = []
        for p in [_DEFAULT_DIR, *(args.systems or [])]:
            if os.path.isdir(p):
                files += [os.path.join(p, n) for n in sorted(os.listdir(p))
                          if n.endswith(".json")]
            elif os.path.exists(p):
                files.append(p)
        for path in files:
            try:
                with open(path) as f:
                    validate_system_dict(json.load(f), source=path)
            except (ValueError, json.JSONDecodeError) as e:
                failures.append(str(e))
        for f in failures:
            print(f"INVALID {f}")
        print(f"catalog check: {len(files)} file(s), "
              f"{len(failures)} failure(s)")
    return 1 if failures else 0


def main(argv: list[str] | None = None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    command = "run"
    if argv and argv[0] in ("run", "validate", "report", "list"):
        command = argv.pop(0)

    ap = argparse.ArgumentParser(
        prog="python -m repro.campaign",
        description="Run, validate, report on a prediction campaign "
                    "from a JSON grid spec (single campaign or suite), "
                    "or list the registered backends/system catalog.")
    if command != "list":
        ap.add_argument("spec", nargs="+" if command != "run" else None,
                        help="path to the campaign/suite spec (JSON)")
    ap.add_argument("--systems", action="append", default=[],
                    metavar="PATH",
                    help="extra system-catalog file or directory of JSON "
                         "records (repeatable); ids become usable on the "
                         "spec 'systems' axis")
    if command == "list":
        ap.add_argument("--check", action="store_true",
                        help="validate every catalog record against the "
                             "schema; exit nonzero on failures")
    if command in ("run", "report"):
        ap.add_argument("--executor", default="thread",
                        choices=("serial", "thread", "process"),
                        help="job executor (default: thread)")
        ap.add_argument("--jobs", type=int, default=None,
                        help="max parallel workers (default: executor's "
                             "choice)")
        ap.add_argument("--cache", default=None, metavar="PATH",
                        help="persistent (H,C,R) cache file shared across "
                             "runs and live workers")
        ap.add_argument("--quiet", action="store_true",
                        help="suppress per-job progress lines")
    if command == "run":
        ap.add_argument("--out", default="artifacts/campaign",
                        help="output directory for results.jsonl/csv + "
                             "summary.json (default: artifacts/campaign)")
        ap.add_argument("--schedule", default="locality",
                        choices=("locality", "grid"),
                        help="job ordering: 'locality' groups jobs by "
                             "shared plan/cache keyset (leader first, "
                             "fingerprint-heavy plans warm the cache "
                             "early); 'grid' is pure grid order "
                             "(default: locality)")
        ap.add_argument("--dry-run", action="store_true",
                        help="print the expanded grid and exit")
        ap.add_argument("--resume", action="store_true",
                        help="crash-safe restart: replay <out>/"
                             "results.jsonl from a previous (possibly "
                             "killed) run — completed rows land in the "
                             "artifacts as-is (tagged 'resumed'), while "
                             "error, missing, and stale rows re-run")
        ap.add_argument("--retries", type=int, default=0, metavar="N",
                        help="re-run a job whose evaluate phase raised, "
                             "up to N extra attempts (default: 0; plan "
                             "and transport failures are not retried)")
        ap.add_argument("--fault-plan", default=None, metavar="PATH",
                        help="test-only: install a deterministic fault-"
                             "injection plan (JSON; see repro.serve."
                             "faults) via the environment so every "
                             "worker process inherits it")
        ap.add_argument("--server", default=None, metavar="URL",
                        help="run on a warm repro.serve daemon (e.g. "
                             "http://127.0.0.1:8733) instead of "
                             "in-process: rows stream back over HTTP and "
                             "the same artifacts are written locally; "
                             "--cache is ignored (the daemon owns the "
                             "store)")
    if command == "report":
        ap.add_argument("--out", default="artifacts/report",
                        help="output directory: campaign artifacts + "
                             "report.json/report.md per campaign "
                             "(default: artifacts/report)")
        ap.add_argument("--results", default=None, metavar="PATH",
                        help="report on an existing results.jsonl instead "
                             "of running the campaign (single campaign "
                             "only)")
        ap.add_argument("--check", action="store_true",
                        help="gate predictions against the checked-in "
                             "golden snapshots (specs/golden/): fail on "
                             "drift beyond tolerance, grid changes, or "
                             "rank inversions")
        ap.add_argument("--update-golden", action="store_true",
                        help="(re)write the golden snapshot and recorded "
                             "reference rows for each campaign from this "
                             "run")
        ap.add_argument("--tolerance", type=float, default=None,
                        help="relative drift tolerance; overrides the "
                             "per-snapshot value (and sets it with "
                             "--update-golden)")
    args = ap.parse_args(argv)

    if command == "list":
        return _list_command(args)

    # every other subcommand resolves kinds/systems through one session
    # (the stable repro.api facade) so user catalogs apply uniformly
    from .. import api
    try:
        session = api.Session(systems=args.systems or ())
    except (OSError, ValueError, TypeError) as e:
        print(f"INVALID catalog: {type(e).__name__}: {e}")
        return 1

    if command == "report":
        return _report_command(args, session=session)

    if command == "validate":
        bad = 0
        for path in args.spec:
            try:
                with open(path) as f:
                    raw = json.load(f)
                if "ladder" in raw or "objectives" in raw:
                    # search specs live beside the campaign grids, so
                    # `validate specs/*.json` must cover both kinds
                    from ..search.spec import SearchSpec
                    sspec = SearchSpec.from_file_dict(raw, path,
                                                      session=session)
                    n = len(sspec.campaign_for_rung(0).expand())
                    print(f"search {sspec.name!r}: {n} candidates, "
                          f"{len(sspec.ladder)}-rung ladder, objectives "
                          f"{list(sspec.objectives)}")
                    print(f"ok {path}")
                    continue
                specs = load_specs(path, session=session)
                for name, spec in specs:
                    spec.validate(session=session)
                    _print_grid(name, spec)
            except (OSError, ValueError, KeyError, TypeError,
                    json.JSONDecodeError) as e:
                print(f"INVALID {path}: {type(e).__name__}: {e}")
                bad += 1
                continue
            print(f"ok {path}")
        return 1 if bad else 0

    from .summary import format_table

    if args.fault_plan:
        # through the environment on purpose: spawned campaign workers
        # (and any daemon this process boots) inherit the plan
        from ..serve import faults
        os.environ[faults.ENV_PLAN] = args.fault_plan

    specs = load_specs(args.spec, session=session)
    if not args.server:
        _preset_device_count(specs)
    multi = len(specs) > 1
    failed = 0
    for name, spec in specs:
        _print_grid(name, spec)
        if args.dry_run:
            for j in spec.expand():
                r = j.to_row()
                print("  " + " × ".join(str(r[k]) for k in
                                        ("workload", "fidelity", "system",
                                         "estimator", "slicer", "topology")))
            continue
        out_dir = os.path.join(args.out, name) if multi else args.out
        resume_rows = None
        if args.resume:
            prev = os.path.join(out_dir, "results.jsonl")
            resume_rows = []
            if os.path.exists(prev):
                resume_rows = _load_results_jsonl(prev)
                print(f"  resuming from {prev} "
                      f"({len(resume_rows)} prior rows)")
            else:
                print(f"  --resume: no {prev} yet, running from scratch")
        if args.server:
            summary = _run_on_server(args, spec, name, multi, out_dir,
                                     resume_rows=resume_rows)
        else:
            from .runner import run_campaign

            result = run_campaign(
                spec, out_dir=out_dir, executor=args.executor,
                max_workers=args.jobs, cache_path=args.cache,
                schedule=args.schedule, progress=not args.quiet,
                session=session, resume_rows=resume_rows,
                retries=args.retries)
            summary = result.summary
            if result.csv_path:
                print(f"  wrote {result.jsonl_path}, {result.csv_path}, "
                      f"{result.summary_path}")
        print(format_table(summary))
        failed += summary["num_failed"]
    return 1 if failed else 0


def _run_on_server(args, spec: CampaignSpec, name: str, multi: bool,
                   out_dir: str, resume_rows: list | None = None) -> dict:
    """Run one campaign on a warm ``repro.serve`` daemon: stream the
    rows back and materialize the standard artifact set locally, so
    downstream tooling (``report --results``, the CI golden diff) sees
    exactly what an in-process run would have written.  A single spec
    file ships as its path (daemon and CLI are localhost peers, and the
    path preserves ``base_dir`` for backend-relative files); suite
    sub-campaigns ship as inline dicts."""
    from ..serve.client import ServeClient, write_campaign_artifacts

    client = ServeClient(args.server)
    kwargs: dict = {"executor": args.executor, "schedule": args.schedule,
                    "max_workers": args.jobs}
    if getattr(args, "retries", 0):
        kwargs["retries"] = args.retries
    if resume_rows is not None:
        kwargs["resume_rows"] = resume_rows
    if multi:
        kwargs["spec"] = spec.to_dict()
    else:
        kwargs["spec_path"] = os.path.abspath(args.spec)
    stream = client.campaign(**kwargs)
    fresh = []
    for row in stream:
        fresh.append(row)
        if not args.quiet:
            tag = (f"{row['step_time_s'] * 1e3:9.3f} ms"
                   if "step_time_s" in row else f"ERROR {row.get('error')}")
            print(f"  [{row['job_id']:4d}] {row['workload']} × "
                  f"{row['system']} × {row['estimator']} × "
                  f"{row['slicer']}: {tag}", flush=True)
    summary = stream.summary or {}
    rows = fresh
    if resume_rows:
        # the daemon replays trusted rows without re-streaming them
        # (this client already has them) — fold them back in, letting
        # freshly streamed rows win and dropping rows outside the grid
        seen = {r.get("job_id") for r in fresh}
        grid = summary.get("num_jobs", len(resume_rows) + len(fresh))
        kept = [dict(r, resumed=True) for r in resume_rows
                if r.get("job_id") not in seen and "error" not in r
                and r.get("job_id", grid) < grid]
        rows = sorted(kept + fresh, key=lambda r: r.get("job_id", 0))
    paths = write_campaign_artifacts(rows, summary, out_dir)
    print(f"  wrote {paths['jsonl']}, {paths['csv']}, {paths['summary']} "
          f"(served by {args.server})")
    return summary


if __name__ == "__main__":
    sys.exit(main())
