"""Campaign summaries: best/worst grid points and cross-architecture
relative-trend ranks (the comparison behind paper Figs 6 and 11 — do
different estimator classes agree on *which system is faster*, even when
their absolute numbers differ?).
"""
from __future__ import annotations

import itertools
from collections import defaultdict


def _point(row: dict) -> dict:
    return {k: row[k] for k in ("workload", "system", "estimator", "slicer",
                                "topology") if k in row}


def summarize(name: str, rows: list[dict]) -> dict:
    ok = [r for r in rows if "error" not in r and "step_time_s" in r]
    failed = [r for r in rows if "error" in r]
    errors_by_type: dict[str, int] = {}
    for r in failed:
        et = r.get("error_type", "unknown")
        errors_by_type[et] = errors_by_type.get(et, 0) + 1
    out: dict = {
        "campaign": name,
        "num_jobs": len(rows),
        "num_ok": len(ok),
        "num_failed": len(failed),
        # stable taxonomy (plan/evaluate/transport): what a resume run
        # reads to report exactly which failure classes it is retrying
        "errors_by_type": errors_by_type,
        "num_resumed": sum(1 for r in rows if r.get("resumed")),
        "failures": [{"job_id": r["job_id"], "error": r["error"],
                      "error_type": r.get("error_type", "unknown"),
                      **_point(r)} for r in failed],
    }
    if not ok:
        return out

    best = min(ok, key=lambda r: r["step_time_s"])
    worst = max(ok, key=lambda r: r["step_time_s"])
    out["best"] = {**_point(best), "step_time_s": best["step_time_s"]}
    out["worst"] = {**_point(worst), "step_time_s": worst["step_time_s"]}
    out["system_ranks"] = system_ranks(ok)
    out["rank_agreement"] = rank_agreement(out["system_ranks"])
    return out


def system_ranks(rows: list[dict]) -> dict:
    """workload -> estimator -> systems ordered fastest-first.

    Step times are averaged over the remaining axes (slicer, topology,
    knobs) so the rank reflects the estimator's overall cross-architecture
    trend for that workload."""
    acc: dict = defaultdict(lambda: defaultdict(lambda: defaultdict(list)))
    for r in rows:
        acc[r["workload"]][r["estimator"]][r["system"]].append(
            r["step_time_s"])
    ranks: dict = {}
    for wl, by_est in acc.items():
        ranks[wl] = {}
        for est, by_sys in by_est.items():
            means = {s: sum(v) / len(v) for s, v in by_sys.items()}
            ranks[wl][est] = sorted(means, key=means.get)
    return ranks


def rank_agreement(ranks: dict) -> dict:
    """Pairwise concordance of system orderings between estimators.

    For each workload and each estimator pair, the fraction of system
    pairs ranked in the same order (Kendall-tau distance, normalized to
    [0, 1]; 1.0 = identical relative trends)."""
    out: dict = {}
    for wl, by_est in ranks.items():
        pairs = {}
        for (e1, r1), (e2, r2) in itertools.combinations(
                sorted(by_est.items()), 2):
            common = [s for s in r1 if s in r2]
            if len(common) < 2:
                continue
            pos1 = {s: i for i, s in enumerate(r1)}
            pos2 = {s: i for i, s in enumerate(r2)}
            concordant = total = 0
            for a, b in itertools.combinations(common, 2):
                total += 1
                if ((pos1[a] - pos1[b]) * (pos2[a] - pos2[b])) > 0:
                    concordant += 1
            pairs[f"{e1} vs {e2}"] = concordant / total if total else 1.0
        if pairs:
            out[wl] = pairs
    return out


def format_table(summary: dict) -> str:
    """Human-readable digest for the CLI."""
    lines = [f"campaign {summary['campaign']}: "
             f"{summary['num_ok']}/{summary['num_jobs']} jobs ok"]
    resume = summary.get("resume")
    if resume:
        by_type = ", ".join(
            f"{k}={v}"
            for k, v in sorted(resume["rerun_errors_by_type"].items()))
        lines.append(
            f"  resume: {resume['resumed']} rows replayed, "
            f"{resume['rerun_errors']} errors retried "
            f"({by_type or 'none'}), "
            f"{resume['missing']} missing, {resume['stale']} stale")
    retries = summary.get("retries")
    if retries and retries.get("rows_retried"):
        lines.append(f"  retries: {retries['rows_retried']} rows retried "
                     f"(up to {retries['configured']} attempts)")
    for r in summary.get("failures", []):
        lines.append(f"  FAILED job {r['job_id']} "
                     f"[{r.get('error_type', 'unknown')}]: {r['error']}")
    if "best" in summary:
        # _point only carries the axes present in the row — rows from a
        # reduced grid (e.g. server resume payloads) may omit some
        def _axes(p: dict) -> str:
            return " × ".join(str(p.get(k, "—"))
                              for k in ("workload", "system", "estimator",
                                        "slicer"))
        b, w = summary["best"], summary["worst"]
        lines.append(
            f"  best : {_axes(b)} = {b['step_time_s'] * 1e3:.3f} ms")
        lines.append(
            f"  worst: {_axes(w)} = {w['step_time_s'] * 1e3:.3f} ms")
    for wl, by_est in summary.get("system_ranks", {}).items():
        for est, order in sorted(by_est.items()):
            lines.append(f"  rank [{wl} / {est}]: {' < '.join(order)}")
    for wl, pairs in summary.get("rank_agreement", {}).items():
        for pair, tau in sorted(pairs.items()):
            lines.append(f"  agreement [{wl}] {pair}: {tau:.2f}")
    cache = summary.get("cache")
    if cache:
        line = (
            f"  cache: {cache['hits']} hits / {cache['misses']} misses "
            f"(hit rate {cache['hit_rate']:.1%}), "
            f"{cache['loaded_entries']} loaded, "
            f"{cache['new_entries']} new entries")
        if cache.get("time_saving_fraction"):
            line += (f", eval time saved "
                     f"{cache['time_saving_fraction']:.1%}")
        lines.append(line)
    if "wall_s" in summary:
        lines.append(f"  wall: {summary['wall_s']:.2f} s")
    return "\n".join(lines)
