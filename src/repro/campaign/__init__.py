"""Prediction-campaign engine: declarative grids of
workloads × systems × estimators × slicers × topologies × knobs, executed
in parallel over one shared persistent (H, C, R) latency cache.

Quickstart::

    from repro.campaign import CampaignSpec, run_campaign

    spec = CampaignSpec.from_dict({
        "name": "sweep",
        "workloads": [{"name": "toy", "arch": "llama3-100m",
                       "seq": 256, "batch": 2, "mode": "forward"}],
        "systems": ["a100", "h100", "b200"],
        "estimators": [{"kind": "roofline"},
                       {"kind": "roofline", "fidelity": "raw",
                        "options": {"mode": "per-op",
                                    "include_overheads": True}}],
        "slicers": ["linear", "dep"],
    })
    result = run_campaign(spec, out_dir="artifacts/sweep",
                          executor="thread", cache_path=".cache/hcr.json")

or from the shell::

    python -m repro.campaign spec.json --out artifacts/sweep
"""
from .runner import CampaignResult, run_campaign
from .spec import (CampaignSpec, EstimatorSpec, JobSpec, TopologySpec,
                   WorkloadSpec)

__all__ = [
    "CampaignSpec", "CampaignResult", "EstimatorSpec", "JobSpec",
    "TopologySpec", "WorkloadSpec", "run_campaign",
]
