"""Prediction-campaign engine: declarative grids of
workloads × systems × estimators × slicers × topologies × knobs, executed
in parallel over one shared persistent (H, C, R) latency cache.

Workloads come from pre-exported IR on disk, from jax exports of
registered archs (``mode="forward"`` or ``mode="train"`` — the latter a
full train step with optimizer state and mesh shardings), or from
synthesized GEMM modules.  Full field reference: ``docs/campaign.md``;
cache semantics: ``docs/caching.md``.

Quickstart::

    from repro.campaign import CampaignSpec, run_campaign

    spec = CampaignSpec.from_dict({
        "name": "sweep",
        "workloads": [{"name": "llama3-100m", "arch": "llama3-100m",
                       "mode": "train", "mesh": [4, 1],
                       "seq": 256, "batch": 4}],
        "systems": ["a100", "h100", "b200"],
        "estimators": [{"kind": "roofline"},
                       {"kind": "roofline", "fidelity": "raw",
                        "options": {"mode": "per-op",
                                    "include_overheads": True}}],
        "slicers": ["linear", "dep"],
    })
    result = run_campaign(spec, out_dir="artifacts/sweep",
                          executor="process",
                          cache_path=".cache/hcr.jsonl")

or from the shell (``specs/paper_full.json`` reproduces every paper
figure grid)::

    python -m repro.campaign run spec.json --out artifacts/sweep
    python -m repro.campaign validate spec.json
"""
from .report import build_report, check_rows, make_golden, render_markdown
from .spec import (CampaignSpec, EstimatorSpec, JobSpec, TopologySpec,
                   WorkloadSpec)

__all__ = [
    "CampaignSpec", "CampaignResult", "EstimatorSpec", "JobSpec",
    "TopologySpec", "WorkloadSpec", "run_campaign",
    "build_report", "check_rows", "make_golden", "render_markdown",
]


def __getattr__(name):
    """Lazy re-export of the runner (PEP 562).

    Spec and report handling are pure stdlib; the runner pulls in the
    estimator stack (numpy, and jax for arch exports).  Deferring that
    import keeps ``python -m repro.campaign validate`` and ``report
    --results`` usable in minimal environments — e.g. the CI docs job,
    which installs nothing."""
    if name in ("CampaignResult", "run_campaign"):
        from . import runner
        return getattr(runner, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
