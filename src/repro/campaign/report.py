"""Campaign evaluation reports + golden-prediction regression checks.

This module turns any campaign result set (in-memory rows, or a streamed
``results.jsonl``) into the paper's evaluation artifacts:

* **accuracy** — per-figure MAPE of every estimator against recorded
  reference rows (``specs/references/<campaign>.json``; offline, the
  recorded reference is the analytical baseline standing in for the
  paper's measured hardware);
* **rank preservation** — Kendall-τ and Spearman-ρ between every pair of
  (estimator-fidelity) columns, along both trend axes: do two estimators
  order *systems* the same way for each workload (the cross-architecture
  claim, Figs 6/11), and do they order *workloads* the same way on each
  system (the scaling claim, Figs 7/9/10)?
* **fidelity comparison** — step-time tables per (workload, system)
  across estimator fidelities, with ratios against the grid's reference
  estimator;
* **golden snapshots** — checked-in per-grid-point predictions
  (``specs/golden/<campaign>.json``); :func:`check_rows` fails on any
  prediction drifting beyond tolerance, any grid-shape change, and any
  rank inversion relative to the snapshot.

Everything here is pure stdlib (no numpy/jax): reports can be built from
a results file in a minimal environment, and the ``report`` CLI only
pulls in the runner when it actually has to execute a campaign.
"""
from __future__ import annotations

import itertools
import json
import math
import os
from collections import defaultdict

#: the axes that identify one grid point in a result row (``fidelity`` is
#: the *effective* fidelity the plan costed — part of the prediction's
#: identity, so a fidelity fallback change is a detected drift)
KEY_AXES = ("workload", "fidelity", "system", "estimator", "slicer",
            "topology", "overlap", "straggler_factor", "compression")
#: float prediction fields compared under relative tolerance
PREDICTION_FIELDS = ("step_time_s", "compute_s", "comm_s",
                     "exposed_comm_s")
#: integer structure fields compared exactly
COUNT_FIELDS = ("num_segments", "num_comm")

DEFAULT_TOLERANCE = 0.05


def row_key(row: dict) -> tuple:
    """The grid-point identity of a result row."""
    return tuple(row.get(a) for a in KEY_AXES)


def ok_rows(rows: list[dict]) -> list[dict]:
    return [r for r in rows if "error" not in r and "step_time_s" in r]


# ------------------------- rank statistics (stdlib) -------------------------


def kendall_tau(x: list[float], y: list[float]) -> float:
    """Kendall's τ-b between two paired value lists (ties corrected).

    1.0 = identical orderings, -1.0 = fully inverted, 0.0 = unrelated
    (or degenerate: fewer than two pairs / all ties)."""
    n = len(x)
    if n != len(y):
        raise ValueError("kendall_tau: length mismatch")
    if n < 2:
        return 0.0
    concordant = discordant = ties_x = ties_y = 0
    for (xa, ya), (xb, yb) in itertools.combinations(zip(x, y), 2):
        dx, dy = xa - xb, ya - yb
        if dx == 0 and dy == 0:
            ties_x += 1
            ties_y += 1
        elif dx == 0:
            ties_x += 1
        elif dy == 0:
            ties_y += 1
        elif (dx > 0) == (dy > 0):
            concordant += 1
        else:
            discordant += 1
    n0 = n * (n - 1) // 2
    denom = math.sqrt((n0 - ties_x) * (n0 - ties_y))
    return (concordant - discordant) / denom if denom else 0.0


def _ranks(values: list[float]) -> list[float]:
    """Fractional ranks (1-based, ties averaged)."""
    order = sorted(range(len(values)), key=lambda i: values[i])
    ranks = [0.0] * len(values)
    i = 0
    while i < len(order):
        j = i
        while (j + 1 < len(order)
               and values[order[j + 1]] == values[order[i]]):
            j += 1
        avg = (i + j) / 2 + 1
        for k in range(i, j + 1):
            ranks[order[k]] = avg
        i = j + 1
    return ranks


def spearman_rho(x: list[float], y: list[float]) -> float:
    """Spearman's ρ: Pearson correlation of the fractional ranks."""
    if len(x) != len(y):
        raise ValueError("spearman_rho: length mismatch")
    n = len(x)
    if n < 2:
        return 0.0
    rx, ry = _ranks(x), _ranks(y)
    mx, my = sum(rx) / n, sum(ry) / n
    cov = sum((a - mx) * (b - my) for a, b in zip(rx, ry))
    vx = sum((a - mx) ** 2 for a in rx)
    vy = sum((b - my) ** 2 for b in ry)
    denom = math.sqrt(vx * vy)
    return cov / denom if denom else 0.0


# ----------------------------- trend extraction -----------------------------


def mean_step_times(rows: list[dict], outer: str, inner: str) -> dict:
    """estimator -> outer-axis value -> inner-axis value -> mean step
    seconds (averaged over every remaining axis), for ok rows."""
    acc: dict = defaultdict(lambda: defaultdict(lambda: defaultdict(list)))
    for r in ok_rows(rows):
        acc[r["estimator"]][r[outer]][r[inner]].append(r["step_time_s"])
    return {est: {o: {i: sum(v) / len(v) for i, v in by_inner.items()}
                  for o, by_inner in by_outer.items()}
            for est, by_outer in acc.items()}


def trend_orderings(rows: list[dict]) -> dict:
    """Fastest-first orderings along both trend axes.

    ``{"systems": {workload: {estimator: [system, ...]}},
       "workloads": {system: {estimator: [workload, ...]}}}``

    The ``systems`` orderings are the paper's cross-architecture trend
    (which system is faster?); the ``workloads`` orderings are the
    scaling trend (do predictions track workload size?).  Golden checks
    fail when either inverts."""
    out: dict = {"systems": {}, "workloads": {}}
    for axis, inner in (("systems", "system"), ("workloads", "workload")):
        outer = "workload" if axis == "systems" else "system"
        means = mean_step_times(rows, outer, inner)
        per_outer: dict = defaultdict(dict)
        for est, by_outer in means.items():
            for o, by_inner in by_outer.items():
                # exact ties break by name, so the ordering is a pure
                # function of the values — golden and fresh row sets
                # arrive in different orders and must not disagree on
                # tied entries
                per_outer[o][est] = sorted(
                    by_inner, key=lambda k: (by_inner[k], k))
        out[axis] = {o: dict(sorted(v.items()))
                     for o, v in sorted(per_outer.items())}
    return out


def rank_preservation(rows: list[dict]) -> dict:
    """Kendall-τ / Spearman-ρ for every estimator pair, along both trend
    axes; plus the headline minima over all pairs."""
    out: dict = {"systems": {}, "workloads": {}}
    taus: list[float] = []
    for axis, inner in (("systems", "system"), ("workloads", "workload")):
        outer = "workload" if axis == "systems" else "system"
        means = mean_step_times(rows, outer, inner)
        section: dict = {}
        for e1, e2 in itertools.combinations(sorted(means), 2):
            for o in sorted(set(means[e1]) & set(means[e2])):
                common = sorted(set(means[e1][o]) & set(means[e2][o]))
                if len(common) < 2:
                    continue
                v1 = [means[e1][o][i] for i in common]
                v2 = [means[e2][o][i] for i in common]
                tau = kendall_tau(v1, v2)
                taus.append(tau)
                section.setdefault(o, {})[f"{e1} vs {e2}"] = {
                    "kendall_tau": round(tau, 6),
                    "spearman_rho": round(spearman_rho(v1, v2), 6),
                    "n": len(common),
                }
        out[axis] = section
    out["min_kendall_tau"] = round(min(taus), 6) if taus else None
    out["all_trends_preserved"] = (all(t > 0 for t in taus)
                                   if taus else None)
    return out


# ------------------------------ accuracy (MAPE) -----------------------------


def mape_against_reference(rows: list[dict], reference: dict) -> dict:
    """Per-estimator MAPE (%) of predicted step time against recorded
    reference rows.

    ``reference`` is the checked-in form: ``{"source": ..., "rows":
    [{"workload": ..., "system": ..., "step_time_s": ...}, ...]}``;
    result rows match on (workload, system) and every matching grid
    point contributes one absolute percentage error."""
    ref_vals = {(r["workload"], r["system"]): float(r["step_time_s"])
                for r in reference.get("rows", [])}
    per_est: dict = defaultdict(lambda: {"errors": [], "per_system":
                                         defaultdict(list), "per_workload":
                                         defaultdict(list)})
    for r in ok_rows(rows):
        ref = ref_vals.get((r["workload"], r["system"]))
        if ref is None or ref <= 0:
            continue
        err = abs(r["step_time_s"] - ref) / ref * 100.0
        e = per_est[r["estimator"]]
        e["errors"].append(err)
        e["per_system"][r["system"]].append(err)
        e["per_workload"][r["workload"]].append(err)

    def _mean(v):
        return round(sum(v) / len(v), 3) if v else None

    return {
        "reference_source": reference.get("source", "unknown"),
        "reference_rows": len(ref_vals),
        "mape_pct": {
            est: {
                "overall": _mean(e["errors"]),
                "matched_rows": len(e["errors"]),
                "per_system": {s: _mean(v)
                               for s, v in sorted(e["per_system"].items())},
                "per_workload": {w: _mean(v)
                                 for w, v in
                                 sorted(e["per_workload"].items())},
            }
            for est, e in sorted(per_est.items())
        },
    }


def reference_estimator(rows: list[dict]) -> str | None:
    """The grid's designated reference estimator: the label of the
    lowest-job_id ok row (i.e. the spec's first estimator)."""
    ok = ok_rows(rows)
    if not ok:
        return None
    return min(ok, key=lambda r: r.get("job_id", 0))["estimator"]


def fidelity_table(rows: list[dict]) -> dict:
    """Step-time comparison across estimator fidelities.

    One entry per (workload, system): mean step milliseconds per
    estimator plus each estimator's ratio against the grid's reference
    estimator (>1 = slower prediction than the reference fidelity)."""
    means = mean_step_times(rows, "workload", "system")
    ref = reference_estimator(rows)
    cells: dict = defaultdict(dict)
    for est, by_w in means.items():
        for w, by_s in by_w.items():
            for s, v in by_s.items():
                cells[(w, s)][est] = v
    table = []
    for (w, s), by_est in sorted(cells.items()):
        ref_v = by_est.get(ref)
        table.append({
            "workload": w,
            "system": s,
            "step_time_ms": {e: round(v * 1e3, 6)
                             for e, v in sorted(by_est.items())},
            "ratio_vs_reference": {
                e: round(v / ref_v, 4) if ref_v else None
                for e, v in sorted(by_est.items())},
        })
    return {"reference_estimator": ref, "rows": table}


def cost_table(rows: list[dict]) -> dict:
    """TCO view per (workload, system): mean ``$/step``, ``joules/step``
    and ``perf/$`` over the remaining axes, from the cost columns the
    runner derives off the catalog's per-device ratings.  Rows priced on
    systems without cost/power fields simply don't appear."""
    acc: dict = defaultdict(lambda: defaultdict(list))
    for r in ok_rows(rows):
        for f in ("usd_per_step", "joules_per_step", "perf_per_usd"):
            if f in r:
                acc[(r["workload"], r["system"])][f].append(r[f])
    table = []
    for (w, s), by_f in sorted(acc.items()):
        entry = {"workload": w, "system": s}
        for f, vals in by_f.items():
            entry[f] = sum(vals) / len(vals)
        table.append(entry)
    return {"rows": table}


# --------------------------------- report -----------------------------------


def build_report(name: str, rows: list[dict],
                 reference: dict | None = None) -> dict:
    """The full evaluation report for one campaign's result rows."""
    ok = ok_rows(rows)
    report = {
        "campaign": name,
        "num_rows": len(rows),
        "num_ok": len(ok),
        "num_failed": len(rows) - len(ok),
        "fidelity_comparison": fidelity_table(rows),
        "rank_preservation": rank_preservation(rows),
        "trend_orderings": trend_orderings(rows),
        "cost": cost_table(rows),
    }
    if reference is not None:
        report["accuracy"] = mape_against_reference(rows, reference)
    return report


def _md_table(headers: list[str], rows: list[list]) -> list[str]:
    out = ["| " + " | ".join(headers) + " |",
           "|" + "|".join("---" for _ in headers) + "|"]
    for r in rows:
        out.append("| " + " | ".join(str(c) for c in r) + " |")
    return out


def render_markdown(report: dict) -> str:
    """Human-readable markdown digest of :func:`build_report` output."""
    name = report["campaign"]
    lines = [f"# Campaign report: {name}", "",
             f"{report['num_ok']}/{report['num_rows']} grid points ok."]
    acc = report.get("accuracy")
    if acc:
        lines += ["", f"## Accuracy vs recorded reference "
                      f"({acc['reference_source']})", ""]
        rows = [[est, m["overall"], m["matched_rows"]]
                for est, m in acc["mape_pct"].items()]
        lines += _md_table(["estimator", "MAPE %", "rows"], rows)
    rp = report["rank_preservation"]
    lines += ["", "## Rank preservation (Kendall-τ / Spearman-ρ)", ""]
    if rp["min_kendall_tau"] is not None:
        verdict = ("preserved" if rp["all_trends_preserved"]
                   else "**INVERTED**")
        lines.append(f"All pairwise trends {verdict}; "
                     f"min τ = {rp['min_kendall_tau']}.")
    for axis, label in (("systems", "system ordering per workload"),
                        ("workloads", "workload ordering per system")):
        rows = [[o, pair, s["kendall_tau"], s["spearman_rho"], s["n"]]
                for o, pairs in rp[axis].items()
                for pair, s in pairs.items()]
        if rows:
            lines += ["", f"### {label}", ""]
            lines += _md_table(["group", "estimator pair", "τ", "ρ", "n"],
                               rows)
    fc = report["fidelity_comparison"]
    if fc["rows"]:
        ests = sorted({e for r in fc["rows"] for e in r["step_time_ms"]})
        lines += ["", f"## Fidelity comparison (step ms; ratio vs "
                      f"`{fc['reference_estimator']}`)", ""]
        rows = []
        for r in fc["rows"]:
            cells = [f"{r['step_time_ms'].get(e, '—')}"
                     f" ({r['ratio_vs_reference'].get(e, '—')}×)"
                     for e in ests]
            rows.append([r["workload"], r["system"], *cells])
        lines += _md_table(["workload", "system", *ests], rows)
    cost = report.get("cost", {}).get("rows") or []
    if cost:
        lines += ["", "## Cost model (mean per grid point)", ""]
        body = []
        for r in cost:
            body.append([
                r["workload"], r["system"],
                f"{r['usd_per_step']:.3e}" if "usd_per_step" in r else "—",
                (f"{r['joules_per_step']:.4g}"
                 if "joules_per_step" in r else "—"),
                f"{r['perf_per_usd']:.4g}" if "perf_per_usd" in r else "—"])
        lines += _md_table(
            ["workload", "system", "$/step", "joules/step", "perf/$"], body)
    check = report.get("golden_check")
    if check is not None:
        lines += ["", "## Golden-snapshot check", ""]
        if check["failures"]:
            lines.append(f"**FAILED** ({len(check['failures'])} "
                         "violations):")
            lines += [f"- {f}" for f in check["failures"]]
        else:
            lines.append(f"OK — {check['rows_checked']} grid points "
                         f"within tolerance {check['tolerance']}, "
                         "no rank inversions.")
    return "\n".join(lines) + "\n"


# ----------------------------- golden snapshots -----------------------------


def make_golden(name: str, rows: list[dict], *,
                tolerance: float = DEFAULT_TOLERANCE,
                meta: dict | None = None) -> dict:
    """The checked-in snapshot form of a campaign's predictions: one
    record per grid point (key axes + prediction fields), plus the drift
    tolerance the CI gate applies."""
    ok = ok_rows(rows)
    if len(ok) != len(rows):
        bad = [r.get("error", "?") for r in rows if "error" in r]
        raise ValueError(
            f"golden {name!r}: refusing to snapshot a failing campaign "
            f"({len(bad)} error rows; first: {bad[:1]})")
    dupes = _duplicate_keys(ok)
    if dupes:
        # e.g. two topologies of one kind with no num_devices param get
        # the same label — the snapshot would silently collapse their
        # grid points and the gate would never check the dropped ones
        raise ValueError(
            f"golden {name!r}: grid points are not distinguishable by "
            f"their row keys {sorted(KEY_AXES)}; first collision: "
            f"{dupes[0]}.  Make colliding axis entries distinguishable "
            "— e.g. pair same-label topologies with distinct workloads "
            "via a zip group (the fig9 pattern)")
    golden_rows = []
    for r in sorted(ok, key=row_key):
        rec = {a: r[a] for a in KEY_AXES}
        rec.update({f: r[f] for f in PREDICTION_FIELDS + COUNT_FIELDS})
        golden_rows.append(rec)
    return {
        "campaign": name,
        "tolerance": tolerance,
        "meta": meta or {},
        "rows": golden_rows,
    }


def _duplicate_keys(rows: list[dict]) -> list[tuple]:
    """Row keys shared by more than one row (grid points the key axes
    cannot tell apart — a keyed comparison would silently drop rows)."""
    seen: set = set()
    dupes: list[tuple] = []
    for r in rows:
        k = row_key(r)
        if k in seen:
            dupes.append(k)
        seen.add(k)
    return dupes


def check_rows(golden: dict, rows: list[dict],
               tolerance: float | None = None) -> dict:
    """Compare fresh campaign rows against a golden snapshot.

    Returns ``{"failures": [...], "rows_checked": n, "tolerance": t}``.
    Failures cover: error rows in the fresh run, ambiguous grids
    (duplicate row keys on either side), grid-shape changes
    (missing/extra grid points), any prediction field drifting beyond
    the relative tolerance (count fields compare exactly), and any
    trend-ordering inversion relative to the snapshot."""
    tol = tolerance if tolerance is not None else float(
        golden.get("tolerance", DEFAULT_TOLERANCE))
    name = golden.get("campaign", "campaign")
    failures: list[str] = []
    fresh_ok = ok_rows(rows)
    for r in rows:
        if "error" in r:
            failures.append(
                f"{name}: job {r.get('job_id')} failed: {r['error']}")
    for side, side_rows in (("fresh", fresh_ok),
                            ("golden", golden.get("rows", []))):
        for key in _duplicate_keys(side_rows):
            failures.append(
                f"{name}: duplicate {side} grid point {key} — row keys "
                "must be unique (make colliding axis entries "
                "distinguishable, e.g. via a zip group)")
    fresh = {row_key(r): r for r in fresh_ok}
    gold = {row_key(r): r for r in golden.get("rows", [])}
    for key in sorted(gold.keys() - fresh.keys()):
        failures.append(
            f"{name}: grid point missing from fresh run: {key} "
            "(grid changed? regenerate with --update-golden)")
    for key in sorted(fresh.keys() - gold.keys()):
        failures.append(
            f"{name}: grid point not in golden snapshot: {key} "
            "(grid changed? regenerate with --update-golden)")
    checked = 0
    max_drift = 0.0
    for key in sorted(gold.keys() & fresh.keys()):
        g, f = gold[key], fresh[key]
        checked += 1
        for fieldname in PREDICTION_FIELDS:
            gv, fv = float(g[fieldname]), float(f[fieldname])
            scale = max(abs(gv), 1e-12)
            drift = abs(fv - gv) / scale
            max_drift = max(max_drift, drift)
            if drift > tol:
                failures.append(
                    f"{name}: {key} {fieldname} drifted "
                    f"{drift:.2%} > {tol:.2%} "
                    f"(golden {gv!r}, fresh {fv!r})")
        for fieldname in COUNT_FIELDS:
            if int(g[fieldname]) != int(f[fieldname]):
                failures.append(
                    f"{name}: {key} {fieldname} changed "
                    f"(golden {g[fieldname]}, fresh {f[fieldname]})")
    # rank inversions: orderings must match the snapshot exactly
    golden_trends = trend_orderings(golden.get("rows", []))
    fresh_trends = trend_orderings(fresh_ok)
    for axis in ("systems", "workloads"):
        for group, by_est in golden_trends[axis].items():
            for est, order in by_est.items():
                got = fresh_trends[axis].get(group, {}).get(est)
                if got is not None and got != order:
                    failures.append(
                        f"{name}: rank inversion [{axis} / {group} / "
                        f"{est}]: golden {order} vs fresh {got}")
    # tolerance note: predictions are deterministic — the vectorized
    # evaluate path and the streaming front end are bit-identical to
    # the scalar/legacy ones on a given machine (tests/
    # test_campaign_diff.py, tests/test_parser_diff.py), so on the
    # machine that recorded the golden the observed drift should be
    # exactly 0; the tolerance exists solely to absorb cross-platform
    # libm/BLAS variance between recorder and checker.
    notes = [f"max prediction drift {max_drift:.3e} of tolerance "
             f"{tol:.2%}; expected exactly 0 on the recording machine "
             "(evaluate paths are bit-identical per "
             "tests/test_campaign_diff.py) — the tolerance absorbs "
             "cross-platform float variance only"]
    return {"failures": failures, "rows_checked": checked,
            "tolerance": tol, "max_drift": max_drift, "notes": notes}


def make_reference(name: str, rows: list[dict], *,
                   source: str | None = None) -> dict:
    """Record reference rows for the MAPE section from a campaign run:
    the reference estimator's mean step time per (workload, system).

    Offline, the analytical baseline stands in for the paper's measured
    hardware; the recorded file keeps MAPE stable even when the grid's
    estimator axis later changes."""
    ref = reference_estimator(rows)
    if ref is None:
        raise ValueError(f"reference {name!r}: no ok rows to record")
    means = mean_step_times(rows, "workload", "system").get(ref, {})
    ref_rows = [{"workload": w, "system": s, "step_time_s": v}
                for w, by_s in sorted(means.items())
                for s, v in sorted(by_s.items())]
    return {
        "campaign": name,
        "source": source or (
            f"recorded {ref} predictions (offline stand-in for measured "
            "hardware; see docs/campaign.md#reports)"),
        "estimator": ref,
        "rows": ref_rows,
    }


# --------------------------------- file I/O ---------------------------------


def golden_path(spec_path: str, campaign: str) -> str:
    """Canonical golden location: ``<specdir>/golden/<campaign>.json``."""
    return os.path.join(os.path.dirname(os.path.abspath(spec_path)),
                        "golden", f"{campaign}.json")


def reference_path(spec_path: str, campaign: str) -> str:
    """Canonical reference location:
    ``<specdir>/references/<campaign>.json``."""
    return os.path.join(os.path.dirname(os.path.abspath(spec_path)),
                        "references", f"{campaign}.json")


def load_json(path: str) -> dict | None:
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def write_json(path: str, payload: dict) -> str:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=False)
        f.write("\n")
    return path
