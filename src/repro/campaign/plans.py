"""Plan store: build each (workload, fidelity, slicer) plan exactly once.

Parsing a multi-MB HLO text and slicing it are per-*workload* costs, not
per-*job* costs — every grid point that shares ``(workload, fidelity,
slicer)`` consumes the identical :class:`~repro.core.pipeline.PredictionPlan`.
The store memoizes both stages separately (two slicers share one parsed
``Program``) and can pickle plans to files so process-pool workers load
exactly the plans they execute instead of re-parsing shipped IR text.
"""
from __future__ import annotations

import os
import pickle
import re
import threading

from ..core.ir.graph import Program
from ..core.pipeline import PredictionPlan, build_plan

PLAN_FILE_SUFFIX = ".plan.pkl"

#: (workload name, effective fidelity, slicer) — the sharing identity
PlanKey = tuple


class PlanStore:
    """Memoizing plan builder for one campaign's workload texts.

    ``texts`` maps workload name -> ``{"raw": ..., "optimized": ...}``.
    ``get`` parses at most once per (workload, fidelity) and slices at
    most once per full key, under a lock so concurrent first jobs of a
    thread campaign cannot duplicate the work.  ``parse_count`` /
    ``plans_built`` expose exactly how often each stage ran (benchmarks
    and tests assert on them).
    """

    def __init__(self, texts: dict[str, dict] | None = None):
        self.texts = dict(texts or {})
        self._programs: dict[tuple[str, str], Program] = {}
        self._plans: dict[PlanKey, PredictionPlan] = {}
        self._fingerprints: dict[PlanKey, frozenset] = {}
        self._lock = threading.Lock()
        self.parse_count = 0    # programs parsed: one per (workload, fidelity)
        self.plans_built = 0    # slicer runs: one per (workload, fid, slicer)

    def add_texts(self, texts: dict[str, dict]) -> None:
        """Fold more workload texts into the store (long-lived stores —
        a warm server, a multi-campaign session — grow one store instead
        of rebuilding it per campaign).

        Re-registering a name with *identical* texts keeps its parsed
        programs and plans hot; binding a name to *different* text drops
        everything cached under that name first, so a reused workload
        name can never serve a stale plan."""
        with self._lock:
            for name, t in texts.items():
                old = self.texts.get(name)
                if old == t:
                    continue
                if old is not None:
                    for memo in (self._programs, self._plans,
                                 self._fingerprints):
                        for key in [k for k in memo if k[0] == name]:
                            del memo[key]
                self.texts[name] = t

    def effective_fidelity(self, workload: str, fidelity: str) -> str:
        """The fidelity actually costed: optimized falls back to raw when
        the workload carries no optimized HLO text."""
        if fidelity == "optimized" and not self.texts[workload].get(
                "optimized"):
            return "raw"
        return fidelity

    def key_for(self, job) -> PlanKey:
        """The plan key a :class:`~repro.campaign.spec.JobSpec` resolves
        to (its fidelity made effective against the workload's texts)."""
        return (job.workload,
                self.effective_fidelity(job.workload, job.fidelity),
                job.slicer)

    def get(self, workload: str, fidelity: str,
            slicer: str) -> PredictionPlan:
        """The plan for the key — parse + slice run at most once."""
        fidelity = self.effective_fidelity(workload, fidelity)
        key = (workload, fidelity, slicer)
        with self._lock:
            plan = self._plans.get(key)
            if plan is None:
                plan = build_plan(self._program_locked(workload, fidelity),
                                  slicer=slicer, name=workload,
                                  fidelity=fidelity)
                self.plans_built += 1
                self._plans[key] = plan
        return plan

    def _program_locked(self, workload: str, fidelity: str) -> Program:
        from ..core.ir.parser import parse

        pkey = (workload, fidelity)
        prog = self._programs.get(pkey)
        if prog is None:
            text = self.texts[workload].get(fidelity)
            if text is None:
                raise ValueError(f"workload {workload!r}: no {fidelity} text")
            prog = parse(text)
            self.parse_count += 1
            self._programs[pkey] = prog
        return prog

    @property
    def plans(self) -> dict:
        """The built plans, keyed by plan key (read-only view)."""
        return dict(self._plans)

    def fingerprint_set(self, key: PlanKey) -> frozenset:
        """The plan's distinct region fingerprints as a hashable set —
        the R surface of its cache keys (empty for unbuilt keys).  Two
        plans with equal sets (e.g. the linear and dep slicings of a
        single-region workload) produce identical cache keysets, so the
        scheduler chains their jobs together."""
        memo = self._fingerprints
        fs = memo.get(key)
        if fs is None:
            plan = self._plans.get(tuple(key))
            fs = (frozenset(plan.fingerprints) if plan is not None
                  else frozenset())
            memo[key] = fs
        return fs

    def weight(self, key: PlanKey) -> int:
        """Distinct region fingerprints of the plan — the scheduler's
        'fingerprint-heavy first' ordering weight (0 for unbuilt keys)."""
        return len(self.fingerprint_set(key))

    # --------------------------- plan files ---------------------------

    def dump(self, dir_path: str,
             keys: set | None = None) -> dict[PlanKey, str]:
        """Pickle built plans into ``dir_path``; returns key -> path.

        This is how plans cross the process-pool boundary: workers
        receive the (tiny) path map and unpickle only the plans their
        jobs reference — no workload text ever ships to a worker.
        ``keys`` restricts the dump to the plans one campaign actually
        references (a warm store may hold many more)."""
        os.makedirs(dir_path, exist_ok=True)
        items = sorted(k_p for k_p in self._plans.items()
                       if keys is None or k_p[0] in keys)
        paths: dict[PlanKey, str] = {}
        for i, (key, plan) in enumerate(items):
            slug = re.sub(r"[^\w.-]+", "_", "-".join(key))
            path = os.path.join(dir_path, f"{i:03d}-{slug}{PLAN_FILE_SUFFIX}")
            with open(path, "wb") as f:
                pickle.dump(plan, f, protocol=pickle.HIGHEST_PROTOCOL)
            paths[key] = path
        return paths

    @staticmethod
    def load_file(path: str) -> PredictionPlan:
        """Unpickle one dumped plan (the worker side of :meth:`dump`)."""
        with open(path, "rb") as f:
            return pickle.load(f)
