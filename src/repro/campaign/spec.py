"""Declarative campaign grids and their expansion into prediction jobs.

A :class:`CampaignSpec` is a small JSON-able description of a sweep; every
axis is a list and the grid is the cross product — except axes joined in
a ``zip`` group, which are paired element-wise (the paper's Fig 9 pairs
each scale-out workload with its own fabric; a cross product cannot
express that).  Expansion produces :class:`JobSpec` records made only of
primitives, so they pickle cleanly into worker processes and serialize
verbatim into result rows.
"""
from __future__ import annotations

import itertools
import json
import os
from dataclasses import asdict, dataclass, field

# the estimator/topology vocabularies are OPEN: the registries are the
# single source of truth (builders resolves through the same objects, so
# validation and execution cannot disagree), and membership checks never
# import a backend module — ``validate`` stays usable without numpy/jax
from ..core.catalog import SystemRegistry, default_registry
from ..core.registry import ESTIMATORS, TOPOLOGIES

SLICER_NAMES = ("linear", "dep", "dependency-aware")


def __getattr__(name: str):
    """Back-compat: the historical closed-vocabulary tuples now reflect
    the live registries (``from repro.campaign.spec import
    ESTIMATOR_KINDS`` keeps working and includes plugin kinds)."""
    if name == "ESTIMATOR_KINDS":
        return ESTIMATORS.kinds()
    if name == "TOPOLOGY_KINDS":
        return TOPOLOGIES.kinds()
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

#: the grid axes, in canonical (expansion) order — ``zip`` groups may
#: only name these, and expansion enumerates them in exactly this order
AXIS_FIELDS = ("workloads", "systems", "estimators", "slicers",
               "topologies", "overlap", "straggler_factor", "compression")


@dataclass(frozen=True)
class WorkloadSpec:
    """One workload axis entry.  Exactly one source family must be given:

    * ``stablehlo_path`` / ``hlo_path`` — pre-exported IR text on disk;
    * ``arch`` (+ ``seq``/``batch``/``mode``/``mesh``/…) — export via jax
      from a registered model config (requires jax at campaign-build
      time).  ``mode="forward"`` exports one forward pass;
      ``mode="train"`` exports a *full train step* — loss + gradients +
      optimizer update, with abstract optimizer state and mesh shardings
      threaded through the lowering.  ``arch`` ids cover the LM registry
      ("llama3-1b", …) and the ResNet family ("resnet50", …; train-only,
      ``img`` sets the image size).  ``mode="prefill"``/``"decode"`` are
      the *serving* shapes: a jax-free synthesized step from the model
      config's layer dims — prefill processes ``batch × seq`` prompt
      tokens at once; decode emits one token per sequence against a
      ``seq``-deep KV cache (the KV-cache-bound regime), so ``batch``
      and ``seq`` are the serving sweep axes;
    * ``gemm`` — a synthesized single-``dot_general`` StableHLO workload
      (``{"m":.., "n":.., "k":.., "dtype":"bf16"}``) for operator-level
      sweeps like the paper's Fig 10 — no jax required.

    ``mesh`` is the device-mesh shape for arch exports: 2 entries map to
    ("data", "model") axes, 3 to ("pod", "data", "model").  The campaign
    process needs at least ``prod(mesh)`` XLA devices (the CLI presets
    the host-platform device count from the spec before jax starts).

    ``fidelity`` is the *default* program fidelity for this workload; an
    :class:`EstimatorSpec` may override it (the paper's estimator classes
    consume different IR stages: analytical -> optimized, profiling -> raw).
    """
    name: str
    stablehlo_path: str | None = None
    hlo_path: str | None = None
    arch: str | None = None
    gemm: dict | None = None         # {"m","n","k","dtype"} synthesis
    seq: int = 512
    batch: int = 4
    img: int = 224                   # resnet archs: input image size
    mode: str = "forward"            # "forward"|"train"|"prefill"|"decode"
    mesh: tuple | None = None        # device mesh shape for arch exports
    optimizer: str = "adamw"         # train-mode optimizer ("adamw"/"adafactor")
    fidelity: str | None = None      # default: optimized if available

    @classmethod
    def from_dict(cls, d: dict) -> "WorkloadSpec":
        """Build from the JSON dict form (mesh lists become tuples)."""
        d = dict(d)
        if d.get("mesh") is not None:
            d["mesh"] = tuple(int(x) for x in d["mesh"])
        return cls(**d)

    def validate(self) -> None:
        """Reject specs that would run wrong or not at all (exactly one
        source family, known mode/optimizer, sane mesh/gemm fields)."""
        families = [bool(self.stablehlo_path or self.hlo_path),
                    self.arch is not None, self.gemm is not None]
        if sum(families) == 0:
            raise ValueError(
                f"workload {self.name!r}: need stablehlo_path, hlo_path, "
                "arch, or gemm")
        if sum(families) > 1:
            raise ValueError(
                f"workload {self.name!r}: give exactly one source family "
                "(stablehlo_path/hlo_path, arch, or gemm) — extra sources "
                "would be silently ignored")
        if self.mode not in ("forward", "train", "prefill", "decode"):
            raise ValueError(
                f"workload {self.name!r}: mode must be 'forward', "
                f"'train', 'prefill', or 'decode', got {self.mode!r}")
        if self.mode in ("prefill", "decode") and self.arch is None:
            raise ValueError(
                f"workload {self.name!r}: mode {self.mode!r} needs an "
                "arch (the serving step is synthesized from the model "
                "config's layer shapes)")
        if self.gemm is not None:
            missing = [k for k in ("m", "n", "k") if k not in self.gemm]
            if missing:
                raise ValueError(
                    f"workload {self.name!r}: gemm spec missing {missing}")
        if self.mesh is not None and len(self.mesh) not in (2, 3):
            raise ValueError(
                f"workload {self.name!r}: mesh must have 2 (data, model) "
                f"or 3 (pod, data, model) entries, got {self.mesh}")
        if self.optimizer not in ("adamw", "adafactor"):
            raise ValueError(
                f"workload {self.name!r}: unknown optimizer "
                f"{self.optimizer!r}")


@dataclass(frozen=True)
class EstimatorSpec:
    """One estimator axis entry.

    kinds: ``roofline`` (options: mode, include_overheads), ``systolic``
    (options: preset), ``mixed`` (systolic primary + roofline fallback;
    options: preset), ``profiling`` (host execution, roofline-projected
    onto the grid system; options: runs).
    """
    kind: str = "roofline"
    options: tuple = ()              # sorted (key, value) pairs — hashable
    fidelity: str | None = None      # override workload fidelity

    @classmethod
    def from_dict(cls, d: dict) -> "EstimatorSpec":
        """Build from the JSON dict form (options dict becomes sorted
        key/value pairs so the spec stays hashable and picklable)."""
        d = dict(d)
        opts = d.pop("options", {}) or {}
        return cls(options=tuple(sorted(opts.items())), **d)

    @property
    def options_dict(self) -> dict:
        """The options pairs as a plain dict."""
        return dict(self.options)

    #: option names the builtin estimator kinds consume — the only ones
    #: :attr:`label` spells out readably
    _LABEL_OPTIONS = ("mode", "include_overheads", "preset", "runs")

    @property
    def label(self) -> str:
        """Unique within any well-formed estimator axis: every field that
        distinguishes two entries appears (summaries and consumer index
        dicts key rows on this).

        Builtin option names render readably; any OTHER options — a
        plugin kind's knobs, a ``table`` estimator's profile path —
        contribute a stable 8-hex digest, so two custom-kind entries
        differing only in such options cannot alias to one label (which
        would silently merge their rows in every label-keyed consumer).
        """
        opts = self.options_dict
        bits = [self.kind]
        if opts.get("mode"):
            bits.append(str(opts["mode"]))
        if opts.get("include_overheads"):
            bits.append("ovh")
        if opts.get("preset"):
            bits.append(str(opts["preset"]))
        if opts.get("runs"):
            bits.append(f"runs{opts['runs']}")
        extra = tuple((k, v) for k, v in self.options
                      if k not in self._LABEL_OPTIONS)
        if extra:
            import hashlib
            bits.append(hashlib.sha1(
                repr(extra).encode()).hexdigest()[:8])
        label = "-".join(bits)
        if self.fidelity:
            label += f"@{self.fidelity}"
        return label


@dataclass(frozen=True)
class TopologySpec:
    """One topology axis entry.

    ``auto`` derives the topology family from the grid system's
    interconnect record (all-to-all node for GPUs, torus for TPUs), which
    is what keeps a single grid meaningful across architectures.
    Explicit kinds: ``a2a``, ``dragonfly``, ``torus``, ``multipod``.
    """
    kind: str = "auto"
    params: tuple = ()               # sorted (key, value) pairs

    @classmethod
    def from_dict(cls, d: dict) -> "TopologySpec":
        """Build from the JSON dict form (list params, e.g. torus dims,
        become tuples; params become sorted pairs)."""
        d = dict(d)
        params = d.pop("params", {}) or {}
        for k, v in list(params.items()):
            if isinstance(v, list):
                params[k] = tuple(v)
        return cls(params=tuple(sorted(params.items())), **d)

    @property
    def params_dict(self) -> dict:
        """The params pairs as a plain dict."""
        return dict(self.params)

    @property
    def label(self) -> str:
        """Short id used in result rows (kind + device count if given)."""
        n = self.params_dict.get("num_devices")
        return f"{self.kind}{n}" if n else self.kind


@dataclass(frozen=True)
class JobSpec:
    """One fully expanded grid point — primitives only, picklable."""
    job_id: int
    workload: str
    fidelity: str
    system: str
    estimator: EstimatorSpec
    slicer: str
    topology: TopologySpec
    overlap: bool = False
    straggler_factor: float = 1.0
    compression: float = 1.0

    def to_row(self) -> dict:
        """The job's axes as a flat result-row prefix."""
        return {
            "job_id": self.job_id,
            "workload": self.workload,
            "fidelity": self.fidelity,
            "system": self.system,
            "estimator": self.estimator.label,
            "slicer": self.slicer,
            "topology": self.topology.label,
            "overlap": self.overlap,
            "straggler_factor": self.straggler_factor,
            "compression": self.compression,
        }

    def cache_group(self, regions_key) -> tuple:
        """The identity under which jobs share an exact (H, C, R) cache
        keyset: same regions R, same system (H), same estimator spec
        (C + config).  ``regions_key`` is any hashable identity for R —
        the runner passes the plan's fingerprint set, so two slicings
        with identical regions land in one group.  Jobs in one cache
        group differ only in topology/overlap/straggler/compression —
        axes the compute cache never sees — so a group's first job
        evaluates every key and its siblings are pure hits."""
        return (regions_key, self.system, self.estimator)


@dataclass
class CampaignSpec:
    """The declarative grid.  Every axis is a list; grid = cross product
    of the axes, except that axes named together in a ``zip_axes`` group
    (JSON key ``"zip"``) are paired element-wise — entry *i* of each
    zipped axis only ever appears with entry *i* of its partners.

    Zipped axes must have equal lengths.  Per-element knobs that vary
    *with* a zipped axis live on the element specs themselves (e.g. each
    :class:`WorkloadSpec` carries its own ``mesh``/``batch``), so a
    (workload, fabric) pairing like the paper's Fig 9 scale-out is one
    spec: zip ``workloads`` with ``topologies`` and give each workload
    its own mesh and batch.
    """
    name: str = "campaign"
    workloads: list[WorkloadSpec] = field(default_factory=list)
    systems: list[str] = field(default_factory=lambda: ["a100"])
    estimators: list[EstimatorSpec] = field(
        default_factory=lambda: [EstimatorSpec()])
    slicers: list[str] = field(default_factory=lambda: ["linear"])
    topologies: list[TopologySpec] = field(
        default_factory=lambda: [TopologySpec()])
    overlap: list[bool] = field(default_factory=lambda: [False])
    straggler_factor: list[float] = field(default_factory=lambda: [1.0])
    compression: list[float] = field(default_factory=lambda: [1.0])
    zip_axes: list[tuple] = field(default_factory=list)  # JSON key: "zip"
    #: extra system-catalog files/dirs (JSON records, see docs/extending.md)
    #: whose ids the ``systems`` axis may then use; relative paths resolve
    #: against the spec file when loaded via :meth:`from_json`
    system_catalog: list[str] = field(default_factory=list)

    #: the spec file's directory when loaded via :meth:`from_json` (a
    #: plain class attribute — unannotated, so *not* a dataclass field or
    #: spec key) — backends resolve their own relative paths (e.g. a
    #: ``table`` estimator's profile JSON) against it via
    #: ``BuildContext.base_dir``
    base_dir = None

    @classmethod
    def from_dict(cls, d: dict, *, session=None,
                  provided: set[str] | frozenset = frozenset()
                  ) -> "CampaignSpec":
        """Build and validate from the JSON dict form; unknown keys are
        rejected so spec typos fail fast.  ``session`` scopes validation
        to a :class:`repro.api.Session`'s registries; ``provided`` names
        workloads supplied in-memory (no spec source required)."""
        d = dict(d)
        zip_groups = d.pop("zip", [])
        known = {f for f in cls.__dataclass_fields__} - {"zip_axes"}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown campaign spec keys: {sorted(unknown)}")
        spec = cls(
            name=d.get("name", "campaign"),
            workloads=[WorkloadSpec.from_dict(w)
                       for w in d.get("workloads", [])],
            systems=list(d.get("systems", ["a100"])),
            estimators=[EstimatorSpec.from_dict(e)
                        for e in d.get("estimators", [{}])],
            slicers=list(d.get("slicers", ["linear"])),
            topologies=[TopologySpec.from_dict(t)
                        for t in d.get("topologies", [{}])],
            overlap=[bool(o) for o in d.get("overlap", [False])],
            straggler_factor=[float(s)
                              for s in d.get("straggler_factor", [1.0])],
            compression=[float(c) for c in d.get("compression", [1.0])],
            zip_axes=[tuple(g) for g in zip_groups],
            system_catalog=[str(p) for p in d.get("system_catalog", [])],
        )
        spec.validate(provided, session=session)
        return spec

    @classmethod
    def from_file_dict(cls, d: dict, path: str, *, session=None,
                       provided: set[str] | frozenset = frozenset()
                       ) -> "CampaignSpec":
        """:meth:`from_dict` for a dict that came from a spec *file*:
        relative ``system_catalog`` paths resolve against the file and
        the spec remembers its ``base_dir`` (callers that already parsed
        the JSON — e.g. suite loading — use this to avoid re-reading)."""
        d = dict(d)
        base = os.path.dirname(os.path.abspath(path))
        if d.get("system_catalog"):
            d["system_catalog"] = [
                p if os.path.isabs(p) else os.path.join(base, p)
                for p in d["system_catalog"]]
        spec = cls.from_dict(d, session=session, provided=provided)
        spec.base_dir = base
        return spec

    @classmethod
    def from_json(cls, path: str, *, session=None,
                  provided: set[str] | frozenset = frozenset()
                  ) -> "CampaignSpec":
        """Load and validate a spec file (see ``docs/campaign.md``);
        relative ``system_catalog`` paths resolve against the file."""
        with open(path) as f:
            d = json.load(f)
        return cls.from_file_dict(d, path, session=session,
                                  provided=provided)

    def to_dict(self) -> dict:
        """JSON-ready dict form; round-trips through :meth:`from_dict`."""
        d = asdict(self)
        for e in d["estimators"]:
            e["options"] = dict(e["options"])
        for t in d["topologies"]:
            t["params"] = dict(t["params"])
        zip_groups = d.pop("zip_axes")
        if zip_groups:
            d["zip"] = [list(g) for g in zip_groups]
        if not d.get("system_catalog"):
            d.pop("system_catalog", None)
        return d

    def system_registry(self,
                        base: SystemRegistry | None = None
                        ) -> SystemRegistry:
        """The catalog this spec's ``systems`` axis resolves against:
        ``base`` (a session's registry, default the shipped catalog)
        overlaid with the spec's own ``system_catalog`` files.

        The catalog files are read from disk once per spec instance (the
        campaign path calls this at load-validate, run-validate, and job
        build); only the cheap scope assembly repeats."""
        base = base if base is not None else default_registry()
        if not self.system_catalog:
            return base
        records = getattr(self, "_catalog_records", None)
        if records is None:
            probe = SystemRegistry(self.system_catalog)
            records = [(sid, probe.get(sid), probe.source(sid))
                       for sid in probe.names()]
            self._catalog_records = records
        scope = base.scope()
        for sid, system, source in records:
            scope.register(sid, system, source=source, replace=True)
        return scope

    def validate(self, provided: set[str] | frozenset = frozenset(), *,
                 session=None) -> None:
        """Reject grids that could not run: empty axes, sourceless
        workloads, and axis values outside the live vocabularies
        (registered estimator/topology kinds, slicer names, catalog
        system ids) — so ``python -m repro.campaign validate`` catches
        typos that would otherwise only surface as all-error rows at run
        time.  Unknown kinds report the registry's did-you-mean.

        ``provided``: workload names supplied in-memory to the runner —
        those need no on-disk/arch source in the spec.  ``session``: a
        :class:`repro.api.Session` whose scoped registries (plugin kinds,
        user catalogs) this spec should validate against."""
        estimators = getattr(session, "estimators", None) or ESTIMATORS
        topologies = getattr(session, "topologies", None) or TOPOLOGIES
        systems = self.system_registry(getattr(session, "systems", None))
        if not self.workloads:
            raise ValueError("campaign spec: at least one workload required")
        for w in self.workloads:
            if w.name not in provided:
                w.validate()
        for axis in ("systems", "estimators", "slicers", "topologies",
                     "overlap", "straggler_factor", "compression"):
            if not getattr(self, axis):
                raise ValueError(f"campaign spec: axis {axis!r} is empty")
        self._validate_zip()
        for e in self.estimators:
            if e.kind not in estimators:
                raise ValueError(
                    f"campaign spec: {estimators.unknown_message(e.kind)}")
        for t in self.topologies:
            if t.kind not in topologies:
                raise ValueError(
                    f"campaign spec: {topologies.unknown_message(t.kind)}")
        for s in self.slicers:
            if s not in SLICER_NAMES:
                raise ValueError(
                    f"campaign spec: unknown slicer {s!r}; "
                    f"have {SLICER_NAMES}")
        for name in self.systems:
            if name not in systems:
                raise ValueError(
                    f"campaign spec: {systems.unknown_message(name)}")

    def _validate_zip(self) -> None:
        """Reject malformed zip groups: unknown axis names, axes claimed
        by more than one group (or twice in one), groups of fewer than
        two axes, and — the silent-mispairing hazard — member axes of
        unequal lengths."""
        seen: dict[str, int] = {}
        for gi, group in enumerate(self.zip_axes):
            if len(group) < 2:
                raise ValueError(
                    f"campaign spec: zip group {list(group)} needs at "
                    "least two axes to pair")
            for axis in group:
                if axis not in AXIS_FIELDS:
                    raise ValueError(
                        f"campaign spec: zip group {list(group)} names "
                        f"unknown axis {axis!r}; axes are {AXIS_FIELDS}")
                if axis in seen:
                    where = ("twice in one group" if seen[axis] == gi
                             else "in more than one zip group")
                    raise ValueError(
                        f"campaign spec: axis {axis!r} appears {where} — "
                        "each axis can be zipped at most once")
                seen[axis] = gi
            lengths = {axis: len(getattr(self, axis)) for axis in group}
            if len(set(lengths.values())) > 1:
                detail = ", ".join(f"{a}={n}" for a, n in lengths.items())
                raise ValueError(
                    f"campaign spec: zip group {list(group)} pairs axes "
                    f"of unequal lengths ({detail}) — zipped axes are "
                    "matched element-wise and must have the same length")

    def _axis_blocks(self) -> list[list[dict]]:
        """The grid's independent blocks, in canonical axis order.

        Each block is a list of ``{axis_field: element}`` dicts: an
        unzipped axis contributes one single-key dict per element; a zip
        group contributes one multi-key dict per paired index.  The grid
        is the cross product of the blocks, so with no zip groups the
        enumeration order is exactly the legacy full cross product.  A
        group is anchored at the canonical position of its earliest
        member axis."""
        group_of = {axis: tuple(g) for g in self.zip_axes for axis in g}
        blocks: list[list[dict]] = []
        consumed: set[str] = set()
        for name in AXIS_FIELDS:
            if name in consumed:
                continue
            group = group_of.get(name)
            if group is None:
                blocks.append([{name: v} for v in getattr(self, name)])
            else:
                consumed.update(group)
                n = len(getattr(self, name))
                blocks.append([{axis: getattr(self, axis)[i]
                                for axis in group} for i in range(n)])
        return blocks

    @property
    def num_points(self) -> int:
        """Grid size: the product of the block lengths (a zip group of
        axes counts once, not once per member)."""
        n = 1
        for block in self._axis_blocks():
            n *= len(block)
        return n

    def expand(self) -> list[JobSpec]:
        """The grid, in deterministic canonical axis order: cross product
        of all axes, with zipped axes advancing together."""
        jobs: list[JobSpec] = []
        for i, combo in enumerate(itertools.product(*self._axis_blocks())):
            d: dict = {}
            for part in combo:
                d.update(part)
            w, est = d["workloads"], d["estimators"]
            fidelity = est.fidelity or w.fidelity or "optimized"
            jobs.append(JobSpec(
                job_id=i, workload=w.name, fidelity=fidelity,
                system=d["systems"], estimator=est, slicer=d["slicers"],
                topology=d["topologies"], overlap=d["overlap"],
                straggler_factor=d["straggler_factor"],
                compression=d["compression"]))
        return jobs
