"""Declarative campaign grids and their expansion into prediction jobs.

A :class:`CampaignSpec` is a small JSON-able description of a sweep; every
axis is a list and the grid is the cross product.  Expansion produces
:class:`JobSpec` records made only of primitives, so they pickle cleanly
into worker processes and serialize verbatim into result rows.
"""
from __future__ import annotations

import itertools
import json
from dataclasses import asdict, dataclass, field


@dataclass(frozen=True)
class WorkloadSpec:
    """One workload axis entry.  Exactly one source must be given:

    * ``stablehlo_path`` / ``hlo_path`` — pre-exported IR text on disk;
    * ``arch`` (+ ``seq``/``batch``/``mode``) — export via jax from a
      registered model config (requires jax at campaign-build time).

    ``fidelity`` is the *default* program fidelity for this workload; an
    :class:`EstimatorSpec` may override it (the paper's estimator classes
    consume different IR stages: analytical -> optimized, profiling -> raw).
    """
    name: str
    stablehlo_path: str | None = None
    hlo_path: str | None = None
    arch: str | None = None
    seq: int = 512
    batch: int = 4
    mode: str = "forward"            # "forward" | "train"
    fidelity: str | None = None      # default: optimized if available

    @classmethod
    def from_dict(cls, d: dict) -> "WorkloadSpec":
        return cls(**d)

    def validate(self) -> None:
        sources = [self.stablehlo_path, self.hlo_path, self.arch]
        if not any(sources):
            raise ValueError(
                f"workload {self.name!r}: need stablehlo_path, hlo_path, "
                "or arch")


@dataclass(frozen=True)
class EstimatorSpec:
    """One estimator axis entry.

    kinds: ``roofline`` (options: mode, include_overheads), ``systolic``
    (options: preset), ``mixed`` (systolic primary + roofline fallback;
    options: preset), ``profiling`` (host execution, roofline-projected
    onto the grid system; options: runs).
    """
    kind: str = "roofline"
    options: tuple = ()              # sorted (key, value) pairs — hashable
    fidelity: str | None = None      # override workload fidelity

    @classmethod
    def from_dict(cls, d: dict) -> "EstimatorSpec":
        d = dict(d)
        opts = d.pop("options", {}) or {}
        return cls(options=tuple(sorted(opts.items())), **d)

    @property
    def options_dict(self) -> dict:
        return dict(self.options)

    @property
    def label(self) -> str:
        """Unique within any well-formed estimator axis: every field that
        distinguishes two entries appears (summaries and consumer index
        dicts key rows on this)."""
        opts = self.options_dict
        bits = [self.kind]
        if opts.get("mode"):
            bits.append(str(opts["mode"]))
        if opts.get("include_overheads"):
            bits.append("ovh")
        if opts.get("preset"):
            bits.append(str(opts["preset"]))
        if opts.get("runs"):
            bits.append(f"runs{opts['runs']}")
        label = "-".join(bits)
        if self.fidelity:
            label += f"@{self.fidelity}"
        return label


@dataclass(frozen=True)
class TopologySpec:
    """One topology axis entry.

    ``auto`` derives the topology family from the grid system's
    interconnect record (all-to-all node for GPUs, torus for TPUs), which
    is what keeps a single grid meaningful across architectures.
    Explicit kinds: ``a2a``, ``dragonfly``, ``torus``, ``multipod``.
    """
    kind: str = "auto"
    params: tuple = ()               # sorted (key, value) pairs

    @classmethod
    def from_dict(cls, d: dict) -> "TopologySpec":
        d = dict(d)
        params = d.pop("params", {}) or {}
        for k, v in list(params.items()):
            if isinstance(v, list):
                params[k] = tuple(v)
        return cls(params=tuple(sorted(params.items())), **d)

    @property
    def params_dict(self) -> dict:
        return dict(self.params)

    @property
    def label(self) -> str:
        n = self.params_dict.get("num_devices")
        return f"{self.kind}{n}" if n else self.kind


@dataclass(frozen=True)
class JobSpec:
    """One fully expanded grid point — primitives only, picklable."""
    job_id: int
    workload: str
    fidelity: str
    system: str
    estimator: EstimatorSpec
    slicer: str
    topology: TopologySpec
    overlap: bool = False
    straggler_factor: float = 1.0
    compression: float = 1.0

    def to_row(self) -> dict:
        return {
            "job_id": self.job_id,
            "workload": self.workload,
            "fidelity": self.fidelity,
            "system": self.system,
            "estimator": self.estimator.label,
            "slicer": self.slicer,
            "topology": self.topology.label,
            "overlap": self.overlap,
            "straggler_factor": self.straggler_factor,
            "compression": self.compression,
        }


@dataclass
class CampaignSpec:
    """The declarative grid.  Every axis is a list; grid = cross product."""
    name: str = "campaign"
    workloads: list[WorkloadSpec] = field(default_factory=list)
    systems: list[str] = field(default_factory=lambda: ["a100"])
    estimators: list[EstimatorSpec] = field(
        default_factory=lambda: [EstimatorSpec()])
    slicers: list[str] = field(default_factory=lambda: ["linear"])
    topologies: list[TopologySpec] = field(
        default_factory=lambda: [TopologySpec()])
    overlap: list[bool] = field(default_factory=lambda: [False])
    straggler_factor: list[float] = field(default_factory=lambda: [1.0])
    compression: list[float] = field(default_factory=lambda: [1.0])

    @classmethod
    def from_dict(cls, d: dict) -> "CampaignSpec":
        d = dict(d)
        known = {f for f in cls.__dataclass_fields__}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown campaign spec keys: {sorted(unknown)}")
        spec = cls(
            name=d.get("name", "campaign"),
            workloads=[WorkloadSpec.from_dict(w)
                       for w in d.get("workloads", [])],
            systems=list(d.get("systems", ["a100"])),
            estimators=[EstimatorSpec.from_dict(e)
                        for e in d.get("estimators", [{}])],
            slicers=list(d.get("slicers", ["linear"])),
            topologies=[TopologySpec.from_dict(t)
                        for t in d.get("topologies", [{}])],
            overlap=[bool(o) for o in d.get("overlap", [False])],
            straggler_factor=[float(s)
                              for s in d.get("straggler_factor", [1.0])],
            compression=[float(c) for c in d.get("compression", [1.0])],
        )
        spec.validate()
        return spec

    @classmethod
    def from_json(cls, path: str) -> "CampaignSpec":
        with open(path) as f:
            return cls.from_dict(json.load(f))

    def to_dict(self) -> dict:
        d = asdict(self)
        for e in d["estimators"]:
            e["options"] = dict(e["options"])
        for t in d["topologies"]:
            t["params"] = dict(t["params"])
        return d

    def validate(self, provided: set[str] | frozenset = frozenset()) -> None:
        """``provided``: workload names supplied in-memory to the runner —
        those need no on-disk/arch source in the spec."""
        if not self.workloads:
            raise ValueError("campaign spec: at least one workload required")
        for w in self.workloads:
            if w.name not in provided:
                w.validate()
        for axis in ("systems", "estimators", "slicers", "topologies",
                     "overlap", "straggler_factor", "compression"):
            if not getattr(self, axis):
                raise ValueError(f"campaign spec: axis {axis!r} is empty")

    @property
    def num_points(self) -> int:
        return (len(self.workloads) * len(self.systems)
                * len(self.estimators) * len(self.slicers)
                * len(self.topologies) * len(self.overlap)
                * len(self.straggler_factor) * len(self.compression))

    def expand(self) -> list[JobSpec]:
        """Cross product of all axes, in deterministic axis order."""
        jobs: list[JobSpec] = []
        grid = itertools.product(
            self.workloads, self.systems, self.estimators, self.slicers,
            self.topologies, self.overlap, self.straggler_factor,
            self.compression)
        for i, (w, system, est, slicer, topo, ovl, strag, comp) in \
                enumerate(grid):
            fidelity = est.fidelity or w.fidelity or "optimized"
            jobs.append(JobSpec(
                job_id=i, workload=w.name, fidelity=fidelity,
                system=system, estimator=est, slicer=slicer, topology=topo,
                overlap=ovl, straggler_factor=strag, compression=comp))
        return jobs
