"""Fault tolerance & elasticity for 1000+-node operation.

Three mechanisms, all exercised by tests and the train loop:

1. **Checkpoint/restart** — CheckpointManager's commit protocol + the train
   loop's `--resume` path.  MTBF-driven save cadence: given per-node MTBF
   and node count, `recommended_interval` balances lost-work vs save cost
   (Young/Daly first-order optimum: sqrt(2 · δ · MTBF_cluster)).

2. **Straggler mitigation** — per-step wall-time EWMA + spike detector.  On
   a real pod the runner reacts by (a) excluding the slow host from the
   next re-mesh, or (b) enabling gradient compression to shrink the
   collective the straggler gates.  The HeSPaS network model quantifies the
   benefit ahead of time (`straggler_factor` in the scheduler).

3. **Elastic re-meshing** — shrink/grow the data axis when nodes fail or
   return.  Because parameters are FSDP-sharded over "data", re-meshing is
   a checkpoint-restore onto a new mesh with different shardings — the
   layout-independent checkpoint format makes this a pure restart path.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field


def recommended_interval(save_cost_s: float, node_mtbf_hours: float,
                         num_nodes: int) -> float:
    """Young/Daly optimal checkpoint interval (seconds)."""
    cluster_mtbf_s = node_mtbf_hours * 3600.0 / max(num_nodes, 1)
    return math.sqrt(2.0 * save_cost_s * cluster_mtbf_s)


@dataclass
class StragglerDetector:
    """EWMA step-time tracker; flags steps slower than ``threshold``×mean."""
    alpha: float = 0.1
    threshold: float = 2.0
    ewma: float = 0.0
    count: int = 0
    flagged: list = field(default_factory=list)

    def observe(self, step: int, wall_s: float) -> bool:
        if self.count == 0:
            self.ewma = wall_s
        is_straggler = (self.count >= 5
                        and wall_s > self.threshold * self.ewma)
        self.ewma = (1 - self.alpha) * self.ewma + self.alpha * wall_s
        self.count += 1
        if is_straggler:
            self.flagged.append((step, wall_s, self.ewma))
        return is_straggler


@dataclass
class ElasticPlan:
    """Re-mesh decision when the healthy-device count changes."""
    mesh_shape: tuple[int, ...]
    mesh_axes: tuple[str, ...]
    global_batch: int
    note: str = ""


def plan_remesh(healthy_devices: int, model_parallel: int,
                global_batch: int, axes=("data", "model")) -> ElasticPlan:
    """Keep the model axis intact (TP must match the weight partitioning);
    shrink the data axis to the largest multiple that fits; rescale the
    batch so per-device load is constant."""
    if healthy_devices < model_parallel:
        raise RuntimeError(
            f"cannot keep model_parallel={model_parallel} with only "
            f"{healthy_devices} devices")
    data = healthy_devices // model_parallel
    # largest power of two <= data keeps collectives ring-friendly
    data = 1 << (data.bit_length() - 1)
    new_batch = max(1, global_batch * data * model_parallel
                    // (healthy_devices))
    # round batch to a multiple of the data axis
    new_batch = max(data, (new_batch // data) * data)
    return ElasticPlan(
        mesh_shape=(data, model_parallel), mesh_axes=tuple(axes),
        global_batch=new_batch,
        note=f"re-meshed to {data}x{model_parallel} "
             f"({healthy_devices} healthy devices)")


@dataclass
class HeartbeatMonitor:
    """Tracks liveness of simulated hosts; drives elastic re-meshing."""
    timeout_s: float = 60.0
    last_seen: dict = field(default_factory=dict)

    def beat(self, host: int, now: float | None = None) -> None:
        self.last_seen[host] = now if now is not None else time.time()

    def dead_hosts(self, now: float | None = None) -> list[int]:
        t = now if now is not None else time.time()
        return [h for h, seen in self.last_seen.items()
                if t - seen > self.timeout_s]
