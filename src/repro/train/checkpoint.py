"""Step-atomic, resumable checkpointing.

Layout (one directory per step, commit-marker protocol — a checkpoint
without COMMIT is ignored, so a crash mid-save can never corrupt restart):

    <dir>/step_000120/
        arrays/<flat-param-name>.npy     (host-gathered shards)
        manifest.json                    (tree structure, shapes, hashes)
        data_state.json                  (data-pipeline cursor)
        COMMIT

Saves run on a background thread (async checkpointing overlaps training);
``restore_latest`` picks the newest committed step.  On a multi-host pod
each host writes only the shards it owns (here: single-host semantics with
the same API)."""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
        return out
    out[prefix.rstrip("/")] = tree
    return out


def _unflatten(flat: dict):
    root: dict = {}
    for path, v in flat.items():
        parts = path.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return root


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------
    def save(self, step: int, state: dict, data_state: dict | None = None,
             blocking: bool = False) -> None:
        # snapshot to host BEFORE handing to the writer thread
        host_state = jax.tree.map(lambda x: np.asarray(x), state)
        if self._thread is not None:
            self._thread.join()

        def _write():
            path = os.path.join(self.directory, f"step_{step:09d}")
            tmp = path + ".tmp"
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(os.path.join(tmp, "arrays"), exist_ok=True)
            flat = _flatten(host_state)
            manifest = {"step": step, "arrays": {}}
            for name, arr in flat.items():
                fn = name.replace("/", "__") + ".npy"
                np.save(os.path.join(tmp, "arrays", fn), arr)
                manifest["arrays"][name] = {
                    "file": fn, "shape": list(arr.shape),
                    "dtype": str(arr.dtype),
                    "sha1": hashlib.sha1(
                        np.ascontiguousarray(arr).tobytes()[:1 << 20]
                    ).hexdigest(),
                }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if data_state is not None:
                with open(os.path.join(tmp, "data_state.json"), "w") as f:
                    json.dump(data_state, f)
            with open(os.path.join(tmp, "COMMIT"), "w") as f:
                f.write("ok")
            shutil.rmtree(path, ignore_errors=True)
            os.replace(tmp, path)
            self._gc()

        if self.async_save and not blocking:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # ------------------------------------------------------------------
    def committed_steps(self) -> list[int]:
        steps = []
        for name in os.listdir(self.directory):
            full = os.path.join(self.directory, name)
            if (name.startswith("step_")
                    and os.path.exists(os.path.join(full, "COMMIT"))):
                steps.append(int(name.split("_")[1]))
        return sorted(steps)

    def restore(self, step: int, shardings=None):
        path = os.path.join(self.directory, f"step_{step:09d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        flat = {}
        for name, info in manifest["arrays"].items():
            arr = np.load(os.path.join(path, "arrays", info["file"]))
            head = hashlib.sha1(
                np.ascontiguousarray(arr).tobytes()[:1 << 20]).hexdigest()
            if head != info["sha1"]:
                raise IOError(f"checkpoint corruption in {name}")
            if arr.dtype.kind == "V":
                # bf16/f8 round-trip through .npy as raw void bytes;
                # re-view with the dtype recorded in the manifest
                import ml_dtypes
                arr = arr.view(np.dtype(getattr(
                    ml_dtypes, info["dtype"], info["dtype"])))
            flat[name] = arr
        tree = _unflatten(flat)
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s), tree, shardings)
        else:
            import jax.numpy as jnp
            tree = jax.tree.map(jnp.asarray, tree)
        data_state = None
        ds_path = os.path.join(path, "data_state.json")
        if os.path.exists(ds_path):
            with open(ds_path) as f:
                data_state = json.load(f)
        return tree, data_state

    def restore_latest(self, shardings=None):
        steps = self.committed_steps()
        if not steps:
            return None, None, -1
        tree, ds = self.restore(steps[-1], shardings)
        return tree, ds, steps[-1]

    def _gc(self) -> None:
        steps = self.committed_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:09d}"),
                          ignore_errors=True)
