"""Optimizers in pure JAX: AdamW and Adafactor (factored second moments).

Optimizer state is fully sharded: each moment inherits its parameter's
sharding (which is itself FSDP-sharded over the "data" axis), so per-device
optimizer bytes scale as 1/|mesh| — required for the 671B MoE config to fit
a v5e pod (see EXPERIMENTS.md §Dry-run memory table)."""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"
    learning_rate: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"
    warmup_steps: int = 100
    # adafactor
    min_dim_size_to_factor: int = 128


def lr_schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1),
                       1.0)
    return cfg.learning_rate * warm


def _global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = _global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


def opt_state_abstract(specs, opt_name: str, mesh=None, rules=None):
    """ShapeDtypeStructs (sharded) for the optimizer state, from ParamSpecs.

    The zero-allocation twin of ``adamw_init``/``adafactor_init`` used to
    *lower* a train step without materializing state (dry-runs, workload
    export).  Moments inherit the parameter sharding (fully sharded
    optimizer); adafactor's factored moments drop the corresponding axes.
    """
    from ..distributed.sharding import param_sharding
    from ..models.params import ParamSpec, is_spec

    def like(spec: ParamSpec, dtype="float32"):
        if mesh is None:
            return jax.ShapeDtypeStruct(spec.shape, jnp.dtype(dtype))
        return jax.ShapeDtypeStruct(
            spec.shape, jnp.dtype(dtype),
            sharding=param_sharding(spec.axes, mesh, rules, spec.shape))

    step = jax.ShapeDtypeStruct((), jnp.int32)
    if opt_name == "adamw":
        return {
            "step": step,
            "m": jax.tree.map(like, specs, is_leaf=is_spec),
            "v": jax.tree.map(like, specs, is_leaf=is_spec),
        }
    # adafactor
    def fac(spec: ParamSpec):
        if len(spec.shape) >= 2 and spec.shape[-1] >= 128 \
                and spec.shape[-2] >= 128:
            vr = ParamSpec(spec.shape[:-1], spec.axes[:-1], dtype="float32")
            vc = ParamSpec((*spec.shape[:-2], spec.shape[-1]),
                           (*spec.axes[:-2], spec.axes[-1]),
                           dtype="float32")
            return {"vr": like(vr), "vc": like(vc)}
        return {"v": like(spec)}

    return {"step": step,
            "v": jax.tree.map(fac, specs, is_leaf=is_spec)}


# --------------------------------------------------------------------------
# AdamW
# --------------------------------------------------------------------------

def adamw_init(params, cfg: OptimizerConfig):
    dt = jnp.dtype(cfg.state_dtype)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, dt), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, dt), params),
    }


def adamw_update(params, grads, state, cfg: OptimizerConfig):
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mh = m_new / c1
        vh = v_new / c2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * \
            p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return (p_new.astype(p.dtype), m_new.astype(m.dtype),
                v_new.astype(v.dtype))

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"step": step, "m": new_m, "v": new_v}, \
        {"grad_norm": gnorm, "lr": lr}


# --------------------------------------------------------------------------
# Adafactor (factored second moment — O(n+m) state for an n×m matrix)
# --------------------------------------------------------------------------

def _factored(shape, min_size) -> bool:
    return len(shape) >= 2 and shape[-1] >= min_size and shape[-2] >= min_size


def adafactor_init(params, cfg: OptimizerConfig):
    dt = jnp.dtype(cfg.state_dtype)

    def one(p):
        if _factored(p.shape, cfg.min_dim_size_to_factor):
            return {"vr": jnp.zeros(p.shape[:-1], dt),
                    "vc": jnp.zeros((*p.shape[:-2], p.shape[-1]), dt)}
        return {"v": jnp.zeros(p.shape, dt)}

    return {"step": jnp.zeros((), jnp.int32),
            "v": jax.tree.map(one, params,
                              is_leaf=lambda x: hasattr(x, "shape"))}


def adafactor_update(params, grads, state, cfg: OptimizerConfig):
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    decay = 1.0 - step.astype(jnp.float32) ** -0.8

    def upd(p, g, v):
        gf = g.astype(jnp.float32)
        g2 = gf * gf + 1e-30
        if "vr" in v:
            vr = decay * v["vr"].astype(jnp.float32) + \
                (1 - decay) * g2.mean(axis=-1)
            vc = decay * v["vc"].astype(jnp.float32) + \
                (1 - decay) * g2.mean(axis=-2)
            denom = (vr[..., None] * vc[..., None, :]
                     / jnp.maximum(vr.mean(axis=-1, keepdims=True)
                                   [..., None], 1e-30))
            update = gf / jnp.sqrt(denom + 1e-30)
            new_v = {"vr": vr.astype(v["vr"].dtype),
                     "vc": vc.astype(v["vc"].dtype)}
        else:
            vv = decay * v["v"].astype(jnp.float32) + (1 - decay) * g2
            update = gf / jnp.sqrt(vv + 1e-30)
            new_v = {"v": vv.astype(v["v"].dtype)}
        # update clipping (RMS <= 1) as in the Adafactor paper
        rms = jnp.sqrt(jnp.mean(update * update) + 1e-30)
        update = update / jnp.maximum(1.0, rms)
        p_new = (p.astype(jnp.float32)
                 - lr * update - lr * cfg.weight_decay * p.astype(jnp.float32))
        return p_new.astype(p.dtype), new_v

    is_v = lambda x: isinstance(x, dict) and ("v" in x or "vr" in x)
    out = jax.tree.map(upd, params, grads, state["v"], is_leaf=None)
    # jax.tree.map with mixed output: separate
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"step": step, "v": new_v}, \
        {"grad_norm": gnorm, "lr": lr}


def make_optimizer(cfg: OptimizerConfig):
    if cfg.name == "adamw":
        return adamw_init, adamw_update
    if cfg.name == "adafactor":
        return adafactor_init, adafactor_update
    raise ValueError(cfg.name)
