"""Training loop: jitted pjit train_step + fault-tolerant outer loop."""
from __future__ import annotations

import time
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, RunConfig
from ..distributed.sharding import ShardingRules, act_sharding, param_sharding
from ..models.params import abstract_params, init_params
from ..models.transformer import forward, model_specs
from .checkpoint import CheckpointManager
from .data import DataConfig, ShardedLoader, SyntheticSource
from .fault_tolerance import StragglerDetector
from .optimizer import OptimizerConfig, make_optimizer


def quantize_int8(g: jax.Array):
    """Symmetric per-tensor int8 quantization (gradient compression)."""
    scale = jnp.max(jnp.abs(g.astype(jnp.float32))) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_int8(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def make_train_step(cfg: ModelConfig, opt_cfg: OptimizerConfig,
                    *, microbatch: int = 0,
                    gradient_compression: bool = False):
    """Builds the pure train_step(params, opt_state, batch) function."""
    _, update_fn = make_optimizer(opt_cfg)

    def loss_fn(params, batch):
        loss, _ = forward(cfg, params, batch)
        return loss

    def compute_grads(params, batch):
        if microbatch and microbatch > 1:
            # gradient accumulation over microbatches via scan
            def split(x):
                b = x.shape[0]
                return x.reshape(microbatch, b // microbatch, *x.shape[1:])

            micro = jax.tree.map(split, batch)

            def acc(carry, mb):
                loss_sum, g_sum = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                g_sum = jax.tree.map(
                    lambda a, b_: a + b_.astype(a.dtype), g_sum, g)
                return (loss_sum + l, g_sum), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                acc, (jnp.zeros((), jnp.float32), g0), micro)
            inv = 1.0 / microbatch
            return loss * inv, jax.tree.map(lambda g: g * inv, grads)
        return jax.value_and_grad(loss_fn)(params, batch)

    def train_step(params, opt_state, batch):
        loss, grads = compute_grads(params, batch)
        if gradient_compression:
            # int8 round-trip: models quantized gradient exchange (the
            # network simulator scales the all-reduce payload to match)
            def rt(g):
                q, s = quantize_int8(g)
                return dequantize_int8(q, s, g.dtype)
            grads = jax.tree.map(rt, grads)
        new_params, new_opt, metrics = update_fn(
            params, grads, opt_state, opt_cfg)
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    return train_step


def train_step_exports(cfg: ModelConfig, seq: int, batch: int, mesh=None,
                       *, rules: ShardingRules | None = None,
                       opt_cfg: OptimizerConfig | None = None,
                       name: str = "bench"):
    """Jitted full train step + abstract (sharded) args for workload export.

    The export-side twin of :func:`train`: builds
    ``train_step(params, opt_state, batch)`` — loss + grad + optimizer
    update — and the zero-allocation ShapeDtypeStruct stand-ins for every
    argument (parameters, optimizer state via
    :func:`~repro.train.optimizer.opt_state_abstract`, and the token
    batch), all carrying mesh shardings when ``mesh`` is given.  This is
    the single source the fig6/fig9/fig11 benchmarks and the campaign
    engine's ``mode="train"`` spec export share, so a campaign prediction
    is bit-identical to a hand-rolled sweep over the same step.

    Returns ``(jitted_step, (params_abs, opt_abs, batch_abs))`` ready for
    :func:`repro.core.pipeline.export_workload`.
    """
    from ..configs.base import ShapeConfig
    from ..models.registry import input_specs
    from .optimizer import opt_state_abstract

    rules = rules or ShardingRules()
    opt_cfg = opt_cfg or OptimizerConfig()
    specs = model_specs(cfg)
    shape = ShapeConfig(name, seq, batch, "train")
    params_abs = abstract_params(specs, mesh, rules)
    batch_abs = input_specs(cfg, shape, mesh, rules)
    opt_abs = opt_state_abstract(specs, opt_cfg.name, mesh, rules)
    step = make_train_step(cfg, opt_cfg)
    jitted = jax.jit(step, donate_argnums=(0, 1))
    return jitted, (params_abs, opt_abs, batch_abs)


@dataclass
class TrainResult:
    steps: int
    final_loss: float
    losses: list
    step_times: list
    restarts: int = 0


def train(run: RunConfig, *, mesh=None, num_steps: int = 20,
          checkpoint_dir: str | None = None, checkpoint_every: int = 0,
          resume: bool = False, log_every: int = 10,
          rules: ShardingRules | None = None,
          inject_failure_at: int | None = None) -> TrainResult:
    """End-to-end training with checkpoint/restart and straggler tracking.

    ``inject_failure_at``: raise a simulated node failure at that step —
    the loop restores from the last committed checkpoint and continues
    (tested in tests/test_fault_tolerance.py)."""
    cfg = run.model
    opt_cfg = OptimizerConfig(
        name=run.optimizer, learning_rate=run.learning_rate,
        weight_decay=run.weight_decay, grad_clip=run.grad_clip)
    init_fn, _ = make_optimizer(opt_cfg)
    rules = rules or ShardingRules()

    specs = model_specs(cfg)
    key = jax.random.PRNGKey(run.seed)
    params = init_params(specs, key)
    if mesh is not None:
        from .data import ShardedLoader  # placement path
        from ..models.params import tree_paths, is_spec

        def place(subtree, spec):
            return jax.device_put(
                subtree, param_sharding(spec.axes, mesh, rules))
        params = jax.tree.map(place, params, specs,
                              is_leaf=lambda x: hasattr(x, "shape")
                              and not isinstance(x, dict))
    opt_state = init_fn(params, opt_cfg)

    data_cfg = DataConfig(
        vocab_size=cfg.vocab_size, seq_len=run.shape.seq_len,
        global_batch=run.shape.global_batch, seed=run.seed,
        frontend=cfg.frontend, d_model=cfg.d_model)
    source = SyntheticSource(data_cfg)
    loader = ShardedLoader(source, mesh, rules) if mesh is not None \
        else source

    ckpt = CheckpointManager(checkpoint_dir) if checkpoint_dir else None
    start_step = 0
    restarts = 0
    if ckpt and resume:
        state, data_state, step = ckpt.restore_latest()
        if step >= 0:
            params, opt_state = state["params"], state["opt"]
            if data_state:
                source.restore(data_state)
            start_step = step + 1

    step_fn = jax.jit(make_train_step(
        cfg, opt_cfg, microbatch=run.microbatch,
        gradient_compression=run.gradient_compression),
        donate_argnums=(0, 1))

    detector = StragglerDetector()
    losses: list[float] = []
    times: list[float] = []
    step = start_step
    failure_armed = inject_failure_at is not None
    while step < num_steps:
        try:
            batch = next(loader)
            t0 = time.perf_counter()
            if failure_armed and step == inject_failure_at:
                failure_armed = False
                raise RuntimeError("injected node failure")
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            detector.observe(step, dt)
            losses.append(loss)
            times.append(dt)
            if log_every and step % log_every == 0:
                print(f"step {step:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"{dt*1e3:.0f} ms")
            if ckpt and checkpoint_every and step % checkpoint_every == 0 \
                    and step > 0:
                ckpt.save(step, {"params": params, "opt": opt_state},
                          source.state())
            step += 1
        except RuntimeError as e:
            if "injected node failure" not in str(e) or ckpt is None:
                raise
            restarts += 1
            ckpt.wait()
            state, data_state, last = ckpt.restore_latest()
            if last < 0:
                raise RuntimeError("failure before first checkpoint") from e
            params, opt_state = state["params"], state["opt"]
            if data_state:
                source.restore(data_state)
            step = last + 1
            print(f"[fault-tolerance] restored step {last}, resuming")
    if ckpt:
        ckpt.wait()
    return TrainResult(steps=step - start_step,
                       final_loss=losses[-1] if losses else float("nan"),
                       losses=losses, step_times=times, restarts=restarts)
