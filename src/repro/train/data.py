"""Synthetic-but-deterministic data pipeline.

Host-side token stream with a resumable cursor (checkpointable), sharded
placement onto the (pod, data) axes, and prefetch double-buffering.  Real
deployments swap ``SyntheticSource`` for a tokenized corpus reader; the
pipeline contract (``__next__`` -> global batch, ``state()``/``restore()``)
stays the same."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    frontend: str = "none"
    d_model: int = 0


class SyntheticSource:
    """Deterministic LM batches from a counter-seeded RNG (resumable)."""

    def __init__(self, cfg: DataConfig, start_step: int = 0):
        self.cfg = cfg
        self.step = start_step

    def state(self) -> dict:
        return {"step": self.step, "seed": self.cfg.seed}

    def restore(self, state: dict) -> None:
        self.step = int(state["step"])

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(cfg.seed * 1_000_003 + self.step)
        self.step += 1
        b, s = cfg.global_batch, cfg.seq_len
        if cfg.frontend == "stub":
            batch = {
                "embeds": rng.standard_normal(
                    (b, s, cfg.d_model), dtype=np.float32),
                "targets": rng.integers(0, cfg.vocab_size, (b, s),
                                        dtype=np.int32),
            }
        else:
            tokens = rng.integers(0, cfg.vocab_size, (b, s + 1),
                                  dtype=np.int32)
            batch = {"tokens": tokens[:, :-1], "targets": tokens[:, 1:]}
        return batch


class ShardedLoader:
    """Places host batches onto the mesh with the activation sharding and
    keeps one batch of prefetch in flight."""

    _AXES = {
        "tokens": ("batch", "seq"),
        "targets": ("batch", "seq"),
        "embeds": ("batch", "seq", "embed"),
        "mrope_positions": ("norm", "batch", "seq"),
    }

    def __init__(self, source, mesh, rules=None):
        from ..distributed.sharding import ShardingRules
        self.source = source
        self.mesh = mesh
        self.rules = rules or ShardingRules()
        self._pending = None

    def _place(self, batch: dict) -> dict:
        from ..distributed.sharding import act_sharding
        out = {}
        for k, v in batch.items():
            arr = jnp.asarray(v)
            sh = act_sharding(self._AXES[k], self.mesh, self.rules,
                              tuple(arr.shape))
            out[k] = jax.device_put(arr, sh)
        return out

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        if self._pending is None:
            self._pending = self._place(next(self.source))
        out = self._pending
        try:
            self._pending = self._place(next(self.source))
        except StopIteration:
            self._pending = None
        return out

    def state(self) -> dict:
        st = self.source.state()
        # one batch is in flight: rewind the cursor by one on restore
        st["step"] = max(0, st["step"] - (1 if self._pending is not None
                                          else 0))
        return st
