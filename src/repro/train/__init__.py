from .checkpoint import CheckpointManager
from .data import DataConfig, ShardedLoader, SyntheticSource
from .fault_tolerance import (ElasticPlan, HeartbeatMonitor,
                              StragglerDetector, plan_remesh,
                              recommended_interval)
from .loop import TrainResult, make_train_step, train
from .optimizer import OptimizerConfig, make_optimizer

__all__ = ["CheckpointManager", "DataConfig", "ShardedLoader",
           "SyntheticSource", "ElasticPlan", "HeartbeatMonitor",
           "StragglerDetector", "plan_remesh", "recommended_interval",
           "TrainResult", "make_train_step", "train", "OptimizerConfig",
           "make_optimizer"]
