"""Logical-axis sharding rules (MaxText-style) -> PartitionSpec/NamedSharding.

Every parameter and activation carries a tuple of *logical* axis names;
rule tables map logical names to mesh axes.  Swapping rule tables re-shards
the whole model without touching model code — this is what the perf-model
pre-flight iterates over when hillclimbing (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# default logical-axis -> mesh-axis rules. None = replicated.
# "data" shards FSDP/batch; "model" shards TP/EP dims; "pod" is the
# multi-pod data-parallel outer axis.
PARAM_RULES: dict[str, object] = {
    "layers": None,          # scan dimension, never sharded
    "embed": "data",         # ZeRO-3: params sharded over the data axis
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "qk_dim": None,
    "v_dim": None,
    "mlp": "model",
    "experts": "model",      # expert parallelism
    "mlp_expert": None,
    "conv": None,
    "ssm_inner": "model",
    "ssm_state": None,
    "ssm_heads": "model",
    "lora": None,
    "norm": None,
}

ACT_RULES: dict[str, object] = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "qk_dim": None,
    "v_dim": None,
    "mlp": "model",
    "experts": "model",
    "vocab": "model",
    "cache_seq": None,
    "ssm_inner": "model",
    "ssm_heads": "model",
    "ssm_state": None,
}

# long-context decode with batch=1: batch cannot use the data axis, so the
# KV-cache length / SSD chunk dimension takes it (sequence parallelism).
ACT_RULES_SEQ_SHARDED = dict(ACT_RULES, **{
    "batch": "pod",
    "cache_seq": "data",
    "seq": "data",
})


@dataclass
class ShardingRules:
    param_rules: dict = field(default_factory=lambda: dict(PARAM_RULES))
    act_rules: dict = field(default_factory=lambda: dict(ACT_RULES))

    def with_overrides(self, *, params: dict | None = None,
                       acts: dict | None = None) -> "ShardingRules":
        pr = dict(self.param_rules)
        ar = dict(self.act_rules)
        pr.update(params or {})
        ar.update(acts or {})
        return ShardingRules(pr, ar)


def _axis_size(mesh: Mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape))[name]


def _resolve(axes: tuple[str, ...], rules: dict, mesh: Mesh,
             shape: tuple[int, ...] | None = None) -> P:
    """Map logical axes to mesh axes, dropping any mapping whose mesh-axis
    product does not evenly divide the dimension (jit argument shardings
    must tile exactly; non-dividing dims — 40 heads or kv=8 on a 16-way
    model axis, vocab 50280, batch 1 — stay replicated on that axis, and
    the waste shows up in the roofline's useful-flops ratio)."""
    parts = []
    used: set[str] = set()
    for i, name in enumerate(axes):
        rule = rules.get(name)
        if rule is None:
            parts.append(None)
            continue
        entries = rule if isinstance(rule, tuple) else (rule,)
        picked = [e for e in entries
                  if e in mesh.axis_names and e not in used]
        if shape is not None:
            dim = shape[i]
            while picked:
                prod = 1
                for e in picked:
                    prod *= _axis_size(mesh, e)
                if dim % prod == 0:
                    break
                picked.pop()          # drop trailing mesh axes until it fits
        used.update(picked)
        if not picked:
            parts.append(None)
        elif len(picked) == 1:
            parts.append(picked[0])
        else:
            parts.append(tuple(picked))
    # trim trailing Nones for cleanliness
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def logical_to_spec(axes: tuple[str, ...], rules: dict, mesh: Mesh,
                    shape: tuple[int, ...] | None = None) -> P:
    return _resolve(axes, rules, mesh, shape)


def param_sharding(axes: tuple[str, ...], mesh: Mesh,
                   rules: ShardingRules | None = None,
                   shape: tuple[int, ...] | None = None) -> NamedSharding:
    r = (rules or ShardingRules()).param_rules
    return NamedSharding(mesh, _resolve(axes, r, mesh, shape))


def act_sharding(axes: tuple[str, ...], mesh: Mesh,
                 rules: ShardingRules | None = None,
                 shape: tuple[int, ...] | None = None) -> NamedSharding:
    r = (rules or ShardingRules()).act_rules
    return NamedSharding(mesh, _resolve(axes, r, mesh, shape))


def constrain(x, axes: tuple[str, ...], rules: ShardingRules | None = None):
    """with_sharding_constraint by logical axes; no-op outside a mesh."""
    mesh = get_abstract_mesh_or_none()
    if mesh is None:
        return x
    r = (rules or ShardingRules()).act_rules
    spec = _resolve(axes, r, mesh, tuple(x.shape))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def get_abstract_mesh_or_none():
    try:
        from jax._src import mesh as mesh_lib
        m = mesh_lib.get_concrete_mesh()
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    try:
        from jax._src import mesh as mesh_lib
        m = mesh_lib.thread_resources.env.physical_mesh
        if m.empty:
            return None
        return m
    except Exception:
        return None
