from .sharding import (ACT_RULES, ACT_RULES_SEQ_SHARDED, PARAM_RULES,
                       ShardingRules, act_sharding, constrain,
                       logical_to_spec, param_sharding)

__all__ = ["ACT_RULES", "ACT_RULES_SEQ_SHARDED", "PARAM_RULES",
           "ShardingRules", "act_sharding", "constrain", "logical_to_spec",
           "param_sharding"]
