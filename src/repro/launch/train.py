"""Training launcher: --arch <id> [--smoke] with checkpointing/restart.

On real hardware this process runs once per host (jax.distributed); in
this container it runs the same code path on the local device.

    PYTHONPATH=src python -m repro.launch.train --arch llama3-100m \
        --steps 100 --ckpt /tmp/ckpt
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "adafactor"])
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    from repro.configs.base import RunConfig, ShapeConfig
    from repro.models import get_config, get_smoke_config
    from repro.train import train

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    if not args.smoke:
        cfg = cfg.scaled(remat="none")  # single-host example scale
    run = RunConfig(
        model=cfg, shape=ShapeConfig("cli", args.seq, args.batch, "train"),
        learning_rate=args.lr, optimizer=args.optimizer,
        microbatch=args.microbatch,
        gradient_compression=args.grad_compression)
    res = train(run, num_steps=args.steps, checkpoint_dir=args.ckpt,
                checkpoint_every=args.ckpt_every, resume=args.resume)
    print(f"finished {res.steps} steps; final loss {res.final_loss:.4f}")


if __name__ == "__main__":
    main()
