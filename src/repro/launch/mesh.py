"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (jax locks the device count on first init, and
only the dry-run process sets --xla_force_host_platform_device_count)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh (smoke tests use (1, 1); benches use host devices)."""
    # axis_types only exists from jax 0.5; Auto is the default there anyway.
    # 0.4.x compat shim: collapse to the axis_types call unconditionally
    # when the jax floor moves to >= 0.6
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)
