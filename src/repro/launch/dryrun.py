import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture × input shape × mesh) cell:
  jax.jit(step, in_shardings=..).lower(**input_specs(arch)).compile()
must succeed on the 16×16 single-pod mesh AND the 2×16×16 multi-pod mesh.
The compiled artifact yields memory_analysis() (fits?), cost_analysis()
(FLOPs/bytes), and — through the paper's own HeSPaS pipeline — the parsed
collective schedule and the three roofline terms vs TPU v5e.

Artifacts: one JSON per cell under --out (default artifacts/dryrun/).

Usage:
  python -m repro.launch.dryrun --arch mamba2-370m --shape train_4k
  python -m repro.launch.dryrun --all                 # every cell
  python -m repro.launch.dryrun --all --multi-pod
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp


def _opt_state_abstract(specs, opt_name, mesh, rules):
    """Back-compat alias; the implementation lives in
    :func:`repro.train.optimizer.opt_state_abstract` (import that instead —
    importing this module forces a 512-device XLA host platform)."""
    from repro.train.optimizer import opt_state_abstract

    return opt_state_abstract(specs, opt_name, mesh, rules)


def build_step(arch: str, shape_name: str, mesh, *, opt_name: str,
               cfg_overrides: dict | None = None,
               rule_overrides: dict | None = None):
    """Returns (jitted_fn, example_args as ShapeDtypeStructs)."""
    from repro.configs.base import SHAPES
    from repro.distributed.sharding import (ACT_RULES_SEQ_SHARDED,
                                            ShardingRules)
    from repro.models import (cache_specs_abstract, get_config, input_specs,
                              model_specs)
    from repro.models.params import abstract_params
    from repro.models.transformer import decode_step, forward, prefill
    from repro.serve.decode import make_serve_step
    from repro.train.loop import make_train_step
    from repro.train.optimizer import OptimizerConfig

    cfg = get_config(arch)
    if cfg_overrides:
        cfg = cfg.scaled(**cfg_overrides)
    shape = SHAPES[shape_name]
    seq_sharded = (shape.name == "long_500k")
    rules = ShardingRules()
    if seq_sharded:
        rules = ShardingRules(rules.param_rules, dict(ACT_RULES_SEQ_SHARDED))
    if rule_overrides:
        rules = rules.with_overrides(**rule_overrides)

    specs = model_specs(cfg)
    params_abs = abstract_params(specs, mesh, rules)
    batch_abs = input_specs(cfg, shape, mesh, rules,
                            seq_sharded=seq_sharded)

    if shape.kind == "train":
        opt_cfg = OptimizerConfig(name=opt_name)
        opt_abs = _opt_state_abstract(specs, opt_name, mesh, rules)
        step = make_train_step(cfg, opt_cfg)
        return jax.jit(step, donate_argnums=(0, 1)), \
            (params_abs, opt_abs, batch_abs), cfg
    if shape.kind == "prefill":
        fn = lambda p, b: prefill(cfg, p, b)
        return jax.jit(fn), (params_abs, batch_abs), cfg
    # decode
    cache_abs = cache_specs_abstract(cfg, shape, mesh, rules,
                                     seq_sharded=seq_sharded)
    serve = make_serve_step(cfg)
    return jax.jit(serve, donate_argnums=(1,)), \
        (params_abs, cache_abs, batch_abs), cfg


def roofline_terms(parsed_cost, collective_bytes_per_chip: float,
                   system) -> dict:
    """The three roofline terms (seconds) on the target system."""
    compute_t = parsed_cost["flops"] / system.flops_for("bf16")
    memory_t = parsed_cost["bytes"] / system.mem_bw
    # axis-aligned torus collectives stripe over both ring directions
    eff_link_bw = system.interconnect.link_bw * 2
    collective_t = collective_bytes_per_chip / eff_link_bw
    terms = {"compute_s": compute_t, "memory_s": memory_t,
             "collective_s": collective_t}
    dom = max(terms, key=terms.get)
    terms["dominant"] = dom
    terms["bound_s"] = terms[dom]
    return terms


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             out_dir: str, cfg_overrides: dict | None = None,
             rule_overrides: dict | None = None, tag: str = "") -> dict:
    from repro.core.ir import parse_hlo, program_cost, total_collective_bytes
    from repro.core.systems import TPU_V5E
    from repro.launch.mesh import make_production_mesh
    from repro.models import get_config, skip_reason

    mesh_name = "2x16x16" if multi_pod else "16x16"
    cell_id = f"{arch}__{shape_name}__{mesh_name}" + (f"__{tag}" if tag else "")
    out_path = os.path.join(out_dir, cell_id + ".json")
    cfg = get_config(arch)
    reason = skip_reason(cfg, shape_name)
    record: dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "status": "skip" if reason else "pending", "skip_reason": reason,
    }
    if reason:
        _write(out_path, record)
        return record

    opt_name = "adafactor" if arch == "deepseek-v3-671b" else "adamw"
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        with mesh:
            jitted, args, cfg = build_step(arch, shape_name, mesh,
                                           opt_name=opt_name,
                                           cfg_overrides=cfg_overrides,
                                           rule_overrides=rule_overrides)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            ma = compiled.memory_analysis()
            mem = {}
            if ma is not None:
                mem = {k: int(getattr(ma, k)) for k in
                       ("argument_size_in_bytes", "output_size_in_bytes",
                        "temp_size_in_bytes", "alias_size_in_bytes",
                        "generated_code_size_in_bytes")}
            print(f"[{cell_id}] memory_analysis:", mem, flush=True)
            ca = {}
            try:
                ca = {k: float(v) for k, v in
                      (compiled.cost_analysis() or {}).items()
                      if isinstance(v, (int, float))}
            except Exception:
                pass
            print(f"[{cell_id}] cost_analysis flops={ca.get('flops')}",
                  flush=True)

            # --- the paper's methodology, applied to our own dry-run ---
            hlo_text = compiled.as_text()
            prog = parse_hlo(hlo_text)
            pc = program_cost(prog)
            coll = total_collective_bytes(prog)
            parsed = {"flops": pc.flops, "bytes": pc.bytes,
                      "transcendentals": pc.transcendentals}
            top_bytes = sorted(pc.bytes_by_op.items(),
                               key=lambda kv: -kv[1])[:12]
            top_flops = sorted(pc.by_op.items(),
                               key=lambda kv: -kv[1])[:8]
            terms = roofline_terms(parsed, sum(coll.values()), TPU_V5E)

            n, active = cfg.param_count()
            tokens = _tokens_per_step(shape_name)
            chips = 512 if multi_pod else 256
            model_flops = 6.0 * active * tokens if shape_name == "train_4k" \
                else 2.0 * active * tokens
            record.update({
                "status": "ok",
                "lower_s": round(t_lower, 2),
                "compile_s": round(t_compile, 2),
                "memory_analysis": mem,
                "cost_analysis": {k: ca[k] for k in
                                  ("flops", "bytes accessed")
                                  if k in ca},
                "parsed_per_chip": parsed,
                "collective_bytes_per_chip": coll,
                "roofline": terms,
                "params_total": n, "params_active": active,
                "model_flops_global": model_flops,
                "model_flops_per_chip": model_flops / chips,
                "useful_flops_ratio": (model_flops / chips)
                / max(pc.flops, 1.0),
                "hlo_bytes_text": len(hlo_text),
                "num_collective_sites": len(prog.collectives()),
                "top_bytes_by_op": dict(top_bytes),
                "top_flops_by_op": dict(top_flops),
            })
    except Exception as e:  # noqa: BLE001 — failures are cell results
        record.update({"status": "fail", "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-4000:]})
    record["wall_s"] = round(time.time() - t0, 2)
    _write(out_path, record)
    status = record["status"]
    print(f"[{cell_id}] {status} wall={record['wall_s']}s", flush=True)
    return record


def _tokens_per_step(shape_name: str) -> float:
    from repro.configs.base import SHAPES
    s = SHAPES[shape_name]
    if s.kind == "decode":
        return float(s.global_batch)          # one token per sequence
    return float(s.global_batch * s.seq_len)


def _write(path: str, record: dict) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(record, f, indent=1)


def main() -> None:
    from repro.configs.base import SHAPES
    from repro.models import ARCH_IDS

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--tag", default="", help="artifact suffix for A/B runs")
    ap.add_argument("--cfg-override", action="append", default=[],
                    help="k=v model-config override (v is literal_eval'd)")
    args = ap.parse_args()
    import ast
    cfg_overrides = {}
    for kv in args.cfg_override:
        k, v = kv.split("=", 1)
        try:
            cfg_overrides[k] = ast.literal_eval(v)
        except (ValueError, SyntaxError):
            cfg_overrides[k] = v

    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) \
        else [args.shape]
    meshes = [False, True] if (args.both_meshes or args.all) \
        else [args.multi_pod]

    results = []
    for multi_pod in meshes:
        for arch in archs:
            for shape in shapes:
                mesh_name = "2x16x16" if multi_pod else "16x16"
                path = os.path.join(
                    args.out, f"{arch}__{shape}__{mesh_name}.json")
                if args.skip_existing and os.path.exists(path):
                    with open(path) as f:
                        prior = json.load(f)
                    if prior.get("status") in ("ok", "skip"):
                        results.append(prior)
                        continue
                results.append(run_cell(
                    arch, shape, multi_pod=multi_pod, out_dir=args.out,
                    cfg_overrides=cfg_overrides or None, tag=args.tag))
    ok = sum(1 for r in results if r["status"] == "ok")
    skip = sum(1 for r in results if r["status"] == "skip")
    fail = [r for r in results if r["status"] == "fail"]
    print(f"\n=== dry-run summary: {ok} ok, {skip} skip, "
          f"{len(fail)} fail / {len(results)} cells ===")
    for r in fail:
        print(f"  FAIL {r['arch']} {r['shape']} {r['mesh']}: {r['error']}")
    raise SystemExit(1 if fail else 0)


if __name__ == "__main__":
    main()
