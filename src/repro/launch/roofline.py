"""Aggregate dry-run artifacts into the §Roofline table.

Reads artifacts/dryrun/*.json (written by repro.launch.dryrun), emits a
markdown table per mesh with the three roofline terms, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPS, and a one-line "what would move the
dominant term" note; also ranks cells for hillclimb selection.

    PYTHONPATH=src python -m repro.launch.roofline [--dir artifacts/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

NOTES = {
    ("compute_s", "train"): "raise MXU occupancy: bigger per-chip batch or "
                            "less remat recompute",
    ("compute_s", "prefill"): "attention flops dominate: tighter flash "
                              "blocks / fewer padded heads",
    ("compute_s", "decode"): "batch more sequences per chip",
    ("memory_s", "train"): "cut HBM traffic: fuse optimizer update, drop "
                           "f32 master copies, rematerialize less",
    ("memory_s", "prefill"): "KV-cache writes + activations: fuse layout "
                             "changes, bf16 cache",
    ("memory_s", "decode"): "weight streaming bound: quantize weights or "
                            "batch more requests per chip",
    ("collective_s", "train"): "shrink gradient all-reduce: reduce-scatter "
                               "+ int8 compression, or overlap with bwd",
    ("collective_s", "prefill"): "TP all-gathers dominate: shard activations "
                                 "on seq instead, or 2D-shard projections",
    ("collective_s", "decode"): "per-token TP collectives: batch tokens or "
                                "switch to data-parallel decode",
}


def load(dir_: str) -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def _fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def table(recs: list[dict], mesh: str) -> str:
    rows = [r for r in recs if r.get("mesh") == mesh]
    out = [f"### Mesh {mesh}\n",
           "| arch | shape | compute | memory | collective | dominant | "
           "useful/HLO flops | bytes/chip fit (16G) | note |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r["status"] == "skip":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | skip | "
                       f"— | — | {r['skip_reason']} |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | FAIL | | | | | | "
                       f"{r.get('error','')[:60]} |")
            continue
        t = r["roofline"]
        kind = ("train" if r["shape"].startswith("train") else
                "prefill" if r["shape"].startswith("prefill") else "decode")
        note = NOTES.get((t["dominant"], kind), "")
        mem = r.get("memory_analysis", {})
        per_chip = (mem.get("argument_size_in_bytes", 0)
                    + mem.get("temp_size_in_bytes", 0)
                    - mem.get("alias_size_in_bytes", 0))
        fit = "yes" if per_chip <= 16e9 else f"NO ({per_chip/1e9:.0f}G)"
        out.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_s(t['compute_s'])} | "
            f"{_fmt_s(t['memory_s'])} | {_fmt_s(t['collective_s'])} | "
            f"{t['dominant'].replace('_s','')} | "
            f"{r['useful_flops_ratio']:.2f} | {fit} | {note} |")
    return "\n".join(out)


def rank_for_hillclimb(recs: list[dict]) -> list[dict]:
    """worst roofline fraction / most collective-bound / most
    paper-representative (largest region count = richest slicing)."""
    ok = [r for r in recs if r.get("status") == "ok"
          and r["mesh"] == "16x16"]
    ranked = []
    for r in ok:
        t = r["roofline"]
        total = t["compute_s"] + 1e-30
        ranked.append({
            "cell": f"{r['arch']}×{r['shape']}",
            "useful_ratio": r["useful_flops_ratio"],
            "collective_frac": t["collective_s"]
            / (t["compute_s"] + t["memory_s"] + t["collective_s"]),
            "dominant": t["dominant"],
            "bound_s": t["bound_s"],
        })
    worst = sorted(ranked, key=lambda x: x["useful_ratio"])[:5]
    coll = sorted(ranked, key=lambda x: -x["collective_frac"])[:5]
    return {"worst_useful_ratio": worst, "most_collective_bound": coll}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--rank", action="store_true")
    args = ap.parse_args()
    recs = load(args.dir)
    print(table(recs, "16x16"))
    print()
    print(table(recs, "2x16x16"))
    if args.rank:
        print()
        print(json.dumps(rank_for_hillclimb(recs), indent=1))


if __name__ == "__main__":
    main()
