"""Stable public facade over the prediction pipeline.

Everything a consumer needs — exporting workloads, building plans,
single predictions, campaigns, and extending the three open vocabularies
(estimator kinds, topology kinds, system catalog) — behind one
documented entry point, so the pipeline internals can keep evolving
without breaking downstream code::

    from repro import api

    session = api.Session(cache_path=".cache/hcr.jsonl")
    w = session.export(jitted_step, params_abs, batch_abs, name="llama")
    p = session.predict(w, system="h100", estimator="roofline")
    result = session.campaign("specs/fig6_gpu.json", executor="thread")

A :class:`Session` owns *scoped* registries (they overlay the global
ones without mutating them) plus the shared (H, C, R) cache store.
Third-party backends register either globally::

    from repro.api import register_estimator

    @register_estimator("my-sim")
    class MySim(...):
        @classmethod
        def from_spec(cls, options, system, context): ...

or per session (``session.register_estimator("my-sim")(MySim)``,
``session.register_system("my-chip", {...})``) — campaign specs then use
the new kinds/ids like any builtin.  See ``docs/extending.md`` for the
full walkthrough.

This module imports only stdlib-weight parts of the package at load
time; jax/numpy are pulled in lazily by the methods that need them.
"""
from __future__ import annotations

import os

from .core.catalog import SystemRegistry, default_registry
from .core.registry import (ESTIMATORS, TOPOLOGIES, BuildContext, Registry,
                            register_estimator, register_topology)
from .core.systems import Interconnect, System, host_system

__all__ = [
    "Session", "System", "Interconnect", "SystemRegistry", "Registry",
    "register_estimator", "register_topology", "host_system",
]


class Session:
    """Registries + cache store + the pipeline verbs that use them.

    ``systems`` seeds extra catalog paths (files or directories of
    system JSON records); ``cache_path`` backs every prediction and
    campaign run with one persistent (H, C, R) store.
    """

    def __init__(self, *, systems: list[str] | tuple = (),
                 cache_path: str | None = None):
        self.estimators = ESTIMATORS.scope()
        self.topologies = TOPOLOGIES.scope()
        self.systems = default_registry().scope()
        for p in systems:
            self.systems.load_path(p)
        self.cache_path = cache_path
        self._store = None
        self._plan_store = None

    # ------------------------- extension surface -------------------------

    def register_estimator(self, kind: str, cls: type | None = None, *,
                           replace: bool = False):
        """Session-scoped :func:`repro.api.register_estimator`."""
        return self.estimators.register(kind, cls, replace=replace)

    def register_topology(self, kind: str, cls: type | None = None, *,
                          replace: bool = False):
        """Session-scoped :func:`repro.api.register_topology`."""
        return self.topologies.register(kind, cls, replace=replace)

    def register_system(self, sid: str, system: System | dict, *,
                        replace: bool = False) -> System:
        """Add a system (object or catalog-record dict) under id ``sid``."""
        return self.systems.register(sid, system, replace=replace)

    def load_systems(self, path: str) -> list[str]:
        """Load a catalog file or directory; returns the new ids."""
        return self.systems.load_path(path)

    def get_system(self, name: str) -> System:
        return self.systems.get(name)

    # --------------------------- cache store ---------------------------

    @property
    def cache_store(self):
        """The session's shared (H, C, R) store (created lazily; a
        :class:`~repro.core.estimators.cache.PersistentCache`, purely
        in-memory when the session has no ``cache_path``).  Every
        predict *and* campaign run through this session shares it, so a
        long-lived session — e.g. the ``repro.serve`` daemon — pays each
        cold miss once across its whole lifetime."""
        if self._store is None:
            from .core.estimators.cache import PersistentCache
            self._store = PersistentCache(self.cache_path)
        return self._store

    @property
    def plan_store(self):
        """The session's warm plan store: parsed programs and sliced
        :class:`~repro.core.pipeline.PredictionPlan`s shared by every
        campaign run through this session (see
        :meth:`~repro.campaign.plans.PlanStore.add_texts` for the
        stale-name invalidation rule)."""
        if self._plan_store is None:
            from .campaign.plans import PlanStore
            self._plan_store = PlanStore()
        return self._plan_store

    def flush_cache(self) -> None:
        """Compact the persistent store (no-op without a ``cache_path``)."""
        from .core.estimators.cache import PersistentCache
        if isinstance(self._store, PersistentCache) and self.cache_path:
            self._store.save(self.cache_path)

    # ------------------------- pipeline verbs -------------------------

    def export(self, jitted, *specs, name: str = "workload", **kw):
        """Export a jitted function's StableHLO/HLO pair (paper stage a);
        see :func:`repro.core.pipeline.export_workload`."""
        from .core.pipeline import export_workload
        return export_workload(jitted, *specs, name=name, **kw)

    def workload(self, *, name: str, stablehlo: str | None = None,
                 hlo: str | None = None,
                 stablehlo_path: str | None = None,
                 hlo_path: str | None = None):
        """Wrap IR text (or text files) as a Workload without jax."""
        from .core.pipeline import Workload
        if stablehlo_path:
            with open(stablehlo_path) as f:
                stablehlo = f.read()
        if hlo_path:
            with open(hlo_path) as f:
                hlo = f.read()
        return Workload(name=name, stablehlo_text=stablehlo, hlo_text=hlo)

    def plan(self, workload, *, slicer: str = "linear",
             fidelity: str | None = None):
        """Parse + slice once into a reusable PredictionPlan (the
        pipeline's plan phase; see :func:`repro.core.pipeline.build_plan`)."""
        from .core.pipeline import build_plan
        fidelity = fidelity or (
            "optimized" if workload.hlo_text else "raw")
        return build_plan(workload.program(fidelity), slicer=slicer,
                          name=workload.name, fidelity=fidelity)

    def predict(self, workload, *, system="a100", estimator="roofline",
                options: dict | None = None, fidelity: str | None = None,
                slicer: str = "linear", topology="auto",
                topology_params: dict | None = None, overlap: bool = False,
                straggler_factor: float = 1.0, compression: float = 1.0,
                use_cache: bool = True):
        """One grid point: cost ``workload`` (a Workload or a prebuilt
        PredictionPlan) on ``system`` with ``estimator`` over
        ``topology``, all resolved through the session's registries.

        ``system`` is a catalog id or a :class:`System`; ``estimator`` a
        registered kind name (with ``options``), an EstimatorSpec, or a
        live ComputeEstimator; ``topology`` a registered kind name (with
        ``topology_params``), a TopologySpec, or a live Topology."""
        from .campaign.builders import build_estimator, build_topology
        from .campaign.spec import EstimatorSpec, TopologySpec
        from .core.estimators.base import ComputeEstimator
        from .core.network import Topology
        from .core.pipeline import PredictionJob, PredictionPlan

        if isinstance(system, System):
            sysm, system_name = system, system.name
        else:
            sysm, system_name = self.systems.get(system), system

        if isinstance(workload, PredictionPlan):
            plan = workload
        else:
            plan = self.plan(workload, slicer=slicer, fidelity=fidelity)

        context = BuildContext(
            system_name=system_name, program=plan.program,
            estimators=self.estimators, topologies=self.topologies,
            systems=self.systems)
        if isinstance(estimator, str):
            estimator = EstimatorSpec(
                kind=estimator,
                options=tuple(sorted((options or {}).items())))
        if isinstance(estimator, EstimatorSpec):
            est = build_estimator(estimator, sysm,
                                  registry=self.estimators, context=context)
        elif isinstance(estimator, ComputeEstimator):
            est = estimator
        else:
            raise TypeError(f"estimator: expected kind name, EstimatorSpec "
                            f"or ComputeEstimator, got {estimator!r}")
        if isinstance(topology, str):
            topology = TopologySpec(
                kind=topology,
                params=tuple(sorted((topology_params or {}).items())))
        if isinstance(topology, TopologySpec):
            topo = build_topology(topology, sysm,
                                  registry=self.topologies, context=context)
        elif isinstance(topology, Topology):
            topo = topology
        else:
            raise TypeError(f"topology: expected kind name, TopologySpec "
                            f"or Topology, got {topology!r}")

        job = PredictionJob(
            estimator=est, topology=topo, slicer=plan.slicer,
            overlap=overlap, straggler_factor=straggler_factor,
            compression=compression, name=plan.name, use_cache=use_cache,
            system_name=sysm.name, cache_store=self.cache_store, plan=plan)
        return job.run()

    def campaign(self, spec, *, workloads: dict | None = None,
                 out_dir: str | None = None, executor: str = "serial",
                 max_workers: int | None = None,
                 cache_path: str | None = None,
                 schedule: str = "locality", progress: bool = False):
        """Run a campaign grid through the session's registries.

        ``spec`` is a CampaignSpec, a spec dict, or a path to a spec
        JSON; everything else mirrors
        :func:`repro.campaign.runner.run_campaign`.  The session's live
        :attr:`cache_store` and :attr:`plan_store` back the run (so
        repeated campaigns through one session re-parse nothing and
        re-pay no cold miss) unless ``cache_path`` redirects the run to
        a different store file."""
        from .campaign.runner import run_campaign
        from .campaign.spec import CampaignSpec
        provided = frozenset(workloads or ())
        if isinstance(spec, str):
            spec = CampaignSpec.from_json(spec, session=self,
                                          provided=provided)
        elif isinstance(spec, dict):
            spec = CampaignSpec.from_dict(spec, session=self,
                                          provided=provided)
        warm = cache_path is None or cache_path == self.cache_path
        return run_campaign(
            spec, workloads=workloads, out_dir=out_dir, executor=executor,
            max_workers=max_workers,
            cache_path=cache_path or self.cache_path,
            cache=self.cache_store if warm else None,
            plan_store=self.plan_store,
            schedule=schedule, progress=progress, session=self)

    def search(self, spec, *, cache_path: str | None = None,
               brute_force: bool = False, progress: bool = False):
        """Run a multi-fidelity what-if search (see ``docs/search.md``).

        ``spec`` is a SearchSpec, a spec dict, or a path to a spec JSON.
        Like :meth:`campaign`, the session's live stores back the run —
        a search after a campaign (or another search) over the same
        workloads re-parses nothing and re-pays no cold miss."""
        from .core.estimators.cache import PersistentCache
        from .search.engine import run_search
        from .search.spec import SearchSpec
        if isinstance(spec, str):
            spec = SearchSpec.from_json(spec, session=self)
        elif isinstance(spec, dict):
            spec = SearchSpec.from_dict(spec, session=self)
        warm = cache_path is None or cache_path == self.cache_path
        cache = self.cache_store if warm else PersistentCache(cache_path)
        return run_search(spec, session=self, cache=cache,
                          plan_store=self.plan_store,
                          brute_force=brute_force, progress=progress)

    # ----------------------------- listing -----------------------------

    def describe(self) -> dict:
        """The live vocabularies, JSON-ready — what ``python -m
        repro.campaign list`` prints: estimator kinds, topology kinds,
        catalog systems with their source files, and what entry-point
        plugin discovery found (``kinds()`` above triggers the scan)."""
        from .core.registry import plugin_status
        return {
            "estimators": list(self.estimators.kinds()),
            "topologies": list(self.topologies.kinds()),
            "systems": [
                {"id": sid, "name": self.systems.get(sid).name,
                 "source": _short_source(self.systems.source(sid))}
                for sid in self.systems.names()],
            "plugins": plugin_status(),
        }


def _short_source(path: str) -> str:
    """Catalog sources relative to CWD when possible (display only)."""
    if not os.path.isabs(path):
        return path
    try:
        rel = os.path.relpath(path)
    except ValueError:
        return path
    return rel if not rel.startswith("..") else os.path.normpath(path)


def load_spec(path: str):
    """Load + validate one campaign spec JSON (facade convenience)."""
    from .campaign.spec import CampaignSpec
    return CampaignSpec.from_json(path)
