#!/usr/bin/env python
"""Record -> fit -> save a learned latency model (the ``learned`` tier).

Builds workload plans (synthesized square GEMMs and/or pre-exported
StableHLO files), records a per-fingerprint profile through a recording
estimator on the source system (offline, the analytical roofline stands
in for measured hardware), fits the per-op-family regression model, and
writes the versioned model JSON a campaign spec's ``{"kind": "learned",
"options": {"model": ...}}`` entry loads::

    PYTHONPATH=src python tools/fit_learned_model.py \\
        --system a100 --gemm 256,512,1024,2048,4096 \\
        --out specs/models/learned-gemm-a100.json

The output is deterministic (pure-float fit, no timestamps), so the
checked-in model regenerates bit-identically; the learned-fidelity
golden grid (``specs/learned_fidelity.json``) depends on that.
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="tools/fit_learned_model.py",
        description="Record a profile and fit a transferable learned "
                    "latency model (see docs/extending.md).")
    ap.add_argument("--system", default="a100",
                    help="source system id from the catalog (default a100)")
    ap.add_argument("--recorder", default="roofline",
                    choices=("roofline", "roofline-per-op"),
                    help="recording estimator (default roofline region "
                         "mode; offline stand-in for measured hardware)")
    ap.add_argument("--gemm", default="",
                    help="comma-separated square GEMM sizes to synthesize "
                         "and record (e.g. 256,512,1024)")
    ap.add_argument("--stablehlo", action="append", default=[],
                    metavar="PATH", help="pre-exported StableHLO file to "
                                         "record (repeatable)")
    ap.add_argument("--out", required=True, metavar="PATH",
                    help="model JSON output path")
    args = ap.parse_args(argv)

    from repro.campaign.builders import _synthesize_gemm
    from repro.campaign.spec import WorkloadSpec
    from repro.core.catalog import default_registry
    from repro.core.estimators import (RooflineEstimator, fit_model,
                                       record_profile, save_model)
    from repro.core.pipeline import build_plan

    system = default_registry().get(args.system)
    regions = []
    sources = []
    for tok in filter(None, (t.strip() for t in args.gemm.split(","))):
        m = int(tok)
        w = _synthesize_gemm(WorkloadSpec(
            name=f"gemm-{m}", fidelity="raw",
            gemm={"m": m, "n": m, "k": m, "dtype": "bf16"}))
        regions += build_plan(w.program("raw"), name=w.name,
                              fidelity="raw").compute_regions
        sources.append(f"gemm-{m}")
    for path in args.stablehlo:
        from repro.core.ir.parser import parse
        with open(path) as f:
            prog = parse(f.read())
        regions += build_plan(prog, name=os.path.basename(path),
                              fidelity="raw").compute_regions
        sources.append(path)
    if not regions:
        ap.error("nothing to record: give --gemm sizes and/or --stablehlo")

    mode = "per-op" if args.recorder.endswith("per-op") else "region"
    recorder = RooflineEstimator(system, mode=mode)
    profile = record_profile(regions, recorder)
    model = fit_model(regions, profile, system, meta={
        "source_system": args.system,
        "recorded_with": args.recorder,
        "workloads": sources,
    })
    save_model(args.out, model)
    fams = {f: fm.n_samples for f, fm in sorted(model.families.items())}
    print(f"fitted {model.meta['entries_fitted']} profile entries -> "
          f"{args.out} (families: {fams})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
