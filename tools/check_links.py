#!/usr/bin/env python3
"""Fail on broken intra-repo markdown links in README.md and docs/*.md.

Checks every ``[text](target)`` whose target is a relative path (external
URLs and pure anchors are skipped): the referenced file or directory must
exist relative to the markdown file.  Used by the CI docs job and by
``tests/test_docs.py``.

Usage::

    python tools/check_links.py [file-or-dir ...]   # default: README.md docs
"""
from __future__ import annotations

import os
import re
import sys

# inline links, excluding images; target up to the first ')' or '#'
_LINK_RE = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)#\s]+)[^)]*\)")
_CODE_FENCE_RE = re.compile(r"^(```|~~~)")


def links_in(path: str) -> list[tuple[int, str]]:
    """(line_number, target) for every link in a markdown file,
    skipping fenced code blocks."""
    out: list[tuple[int, str]] = []
    in_fence = False
    with open(path, encoding="utf-8") as f:
        for lineno, line in enumerate(f, 1):
            if _CODE_FENCE_RE.match(line.strip()):
                in_fence = not in_fence
                continue
            if in_fence:
                continue
            for m in _LINK_RE.finditer(line):
                out.append((lineno, m.group(1)))
    return out


def is_external(target: str) -> bool:
    return target.startswith(("http://", "https://", "mailto:", "#"))


def check_file(path: str) -> list[str]:
    """Human-readable error strings for every broken link in ``path``."""
    errors = []
    base = os.path.dirname(os.path.abspath(path))
    for lineno, target in links_in(path):
        if is_external(target):
            continue
        resolved = os.path.normpath(os.path.join(base, target))
        if not os.path.exists(resolved):
            errors.append(f"{path}:{lineno}: broken link -> {target}")
    return errors


def collect(paths: list[str]) -> list[str]:
    files = []
    for p in paths:
        if os.path.isdir(p):
            files.extend(sorted(
                os.path.join(p, f) for f in os.listdir(p)
                if f.endswith(".md")))
        elif p.endswith(".md"):
            files.append(p)
    return files


def main(argv: list[str]) -> int:
    paths = argv or ["README.md", "docs"]
    files = collect(paths)
    if not files:
        print("check_links: no markdown files found", file=sys.stderr)
        return 1
    errors = []
    for f in files:
        errors.extend(check_file(f))
    for e in errors:
        print(e, file=sys.stderr)
    print(f"check_links: {len(files)} files, {len(errors)} broken links")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
