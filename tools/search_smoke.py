#!/usr/bin/env python3
"""CI smoke for the what-if search engine.

Drives both checked-in search specs the way CI means them to be used:

  1. ``python -m repro.search run <spec> --check`` as a real
     subprocess — the CLI must exit 0 with the frontier matching its
     golden snapshot (``specs/golden/``) at the snapshot's tolerance;
  2. an in-process ladder run per spec whose counters must show the
     optimizer doing its job — candidates pruned below the top rung,
     top-rung evaluations under half the grid, a non-empty frontier —
     followed by a brute-force run whose frontier must be identical
     (prune soundness on the live tree, not just the snapshot);
  3. a warm re-search through the same Session paying zero cold
     misses and at least one cache hit.

Exit 1 on any deviation.  Run from the repo root::

    PYTHONPATH=src python tools/search_smoke.py
"""
from __future__ import annotations

import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

from repro import api  # noqa: E402

SPECS = [os.path.join(REPO, "specs", "search_gemm.json"),
         os.path.join(REPO, "specs", "search_serving.json")]


def fail(msg: str) -> None:
    print(f"SEARCH-SMOKE FAILURE: {msg}")
    raise SystemExit(1)


def cli_golden_check(spec: str) -> None:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.search", "run", spec,
         "--check", "--quiet", "--out",
         os.path.join(REPO, "artifacts", "search-smoke",
                      os.path.basename(spec))],
        cwd=REPO, env=env, capture_output=True, text=True)
    if proc.returncode != 0:
        fail(f"CLI --check failed for {spec}:\n"
             f"{proc.stdout}\n{proc.stderr}")
    if "golden OK" not in proc.stdout:
        fail(f"CLI --check for {spec} exited 0 without 'golden OK':\n"
             f"{proc.stdout}")
    print(f"  cli --check ok: {os.path.basename(spec)}")


def engine_invariants(spec: str) -> None:
    session = api.Session()
    ladder = session.search(spec)
    c = ladder.counters

    pruned = (c["pruned_ceiling"] + c["pruned_intra"]
              + c["pruned_dominated"])
    if pruned <= 0:
        fail(f"{spec}: ladder pruned nothing ({c})")
    if not ladder.frontier:
        fail(f"{spec}: empty frontier ({c})")
    if not 0 < c["top_rung_fraction"] < 0.5:
        fail(f"{spec}: top-rung fraction {c['top_rung_fraction']} "
             f"not in (0, 0.5)")
    if c["top_rung_evaluations"] + pruned + c["infeasible"] \
            < c["candidates"]:
        fail(f"{spec}: counters do not account for the grid ({c})")

    brute = api.Session().search(spec, brute_force=True)
    if brute.frontier != ladder.frontier:
        fail(f"{spec}: ladder frontier {ladder.frontier} != "
             f"brute-force frontier {brute.frontier}")

    warm = session.search(spec)
    if warm.counters["cache_misses"] != 0:
        fail(f"{spec}: warm re-search paid "
             f"{warm.counters['cache_misses']} cold misses")
    if warm.counters["cache_hits"] <= 0:
        fail(f"{spec}: warm re-search recorded no cache hits")
    print(f"  engine ok: {os.path.basename(spec)} — "
          f"{c['frontier_size']} frontier / {c['candidates']} candidates, "
          f"{pruned} pruned, top rung {c['top_rung_evaluations']} "
          f"({c['top_rung_fraction']:.0%}), warm misses 0")


def main() -> None:
    for spec in SPECS:
        cli_golden_check(spec)
        engine_invariants(spec)
    print("search smoke: all checks passed")


if __name__ == "__main__":
    main()
