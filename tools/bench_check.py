#!/usr/bin/env python3
"""Benchmark regression gate over *deterministic* counters.

The ``BENCH_*.json`` perf-trajectory artifacts mix two kinds of numbers:
wall-clock timings (machine-dependent, useless as CI gates) and
deterministic work counters — parse calls, plans built, cache misses,
lock round-trips, duplicate cold misses — that depend only on the code.
This gate compares ONLY the counters, against the expectations recorded
in ``specs/bench_baselines.json``::

    python tools/bench_check.py                     # all baselined files
    python tools/bench_check.py BENCH_serve.json    # just one

Baseline format — one entry per bench file, mapping a dotted path into
the report to exactly one constraint::

    {"BENCH_campaign.json": {
        "executors.serial.parse_calls": {"max": 5},
        "grid.jobs":                    {"equals": 80},
        "parse_call_ratio":             {"min": 16.0}}}

``equals`` pins structural counters (grid shape, miss counts) so silent
changes need a deliberate baseline update; ``max`` bounds work that must
not grow back (parses, lock round-trips); ``min`` floors amortization
ratios.  Exit 1 on any violated constraint or missing counter.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BASELINES = os.path.join(REPO, "specs", "bench_baselines.json")

_OPS = ("equals", "min", "max")


def resolve(report: dict, dotted: str):
    """Walk a dotted path through nested dicts; KeyError when absent."""
    cur = report
    for part in dotted.split("."):
        if not isinstance(cur, dict) or part not in cur:
            raise KeyError(dotted)
        cur = cur[part]
    return cur


def check_value(value, constraint: dict) -> str | None:
    """None when the constraint holds, else the failure description."""
    ops = [k for k in constraint if k in _OPS]
    if len(ops) != 1:
        return f"baseline entry must have exactly one of {_OPS}, " \
               f"got {sorted(constraint)}"
    op = ops[0]
    bound = constraint[op]
    if not isinstance(value, (int, float)) or isinstance(value, bool):
        return f"counter is {value!r}, not a number"
    if op == "equals" and value != bound:
        return f"{value} != {bound}"
    if op == "min" and value < bound:
        return f"{value} < min {bound}"
    if op == "max" and value > bound:
        return f"{value} > max {bound}"
    return None


def check_file(bench_path: str, constraints: dict) -> list[str]:
    """All failures for one bench report (missing file is a failure:
    a gate that silently skips is not a gate)."""
    name = os.path.basename(bench_path)
    if not os.path.exists(bench_path):
        return [f"{name}: report not found at {bench_path} — run the "
                "benchmark first"]
    with open(bench_path) as f:
        report = json.load(f)
    failures = []
    for dotted, constraint in sorted(constraints.items()):
        try:
            value = resolve(report, dotted)
        except KeyError:
            failures.append(f"{name}: counter {dotted!r} missing from "
                            "report")
            continue
        err = check_value(value, constraint)
        if err:
            failures.append(f"{name}: {dotted}: {err}")
        else:
            op = next(k for k in constraint if k in _OPS)
            print(f"  ok {name}: {dotted} = {value} "
                  f"({op} {constraint[op]})")
    return failures


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        description="Gate BENCH_*.json deterministic counters against "
                    "specs/bench_baselines.json.")
    ap.add_argument("bench", nargs="*",
                    help="bench report files to check (default: every "
                         "file named in the baselines)")
    ap.add_argument("--baselines", default=BASELINES,
                    help="baseline expectations file")
    args = ap.parse_args(argv)

    with open(args.baselines) as f:
        baselines = {k: v for k, v in json.load(f).items()
                     if not k.startswith("_")}

    if args.bench:
        targets = {}
        for path in args.bench:
            key = os.path.basename(path)
            if key not in baselines:
                print(f"bench_check: no baselines recorded for {key} "
                      f"(have {sorted(baselines)})")
                return 2
            targets[path] = baselines[key]
    else:
        targets = {os.path.join(REPO, name): cons
                   for name, cons in baselines.items()}

    failures: list[str] = []
    for path, constraints in sorted(targets.items()):
        failures.extend(check_file(path, constraints))
    for fail in failures:
        print(f"BENCH REGRESSION: {fail}")
    n = sum(len(c) for c in targets.values())
    print(f"bench_check: {n} counter(s) across {len(targets)} report(s), "
          f"{len(failures)} failure(s)")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
