#!/usr/bin/env python3
"""CI smoke for prediction-as-a-service: CI as the daemon's first
production client.

Boots a real ``python -m repro.serve`` daemon subprocess (ephemeral
port, Fig 10 GEMM spec preloaded), then drives it the way CI means it
to be used:

  1. a *coalesced duplicate-request pair* — two concurrent identical
     predictions on a workload the daemon has never costed; ``/stats``
     must show exactly one cold miss between them and
     ``duplicate_cold_misses == 0``;
  2. replays ``specs/fig10_gemm.json`` through the HTTP client and
     diffs the streamed rows against the checked-in golden snapshot
     (``specs/golden/``) at the snapshot's own tolerance;
  3. the ``/report`` endpoint's golden check must agree;
  4. graceful shutdown via ``/shutdown``, daemon exits 0.

Exit 1 on any deviation.  Run from the repo root::

    PYTHONPATH=src python tools/serve_smoke.py
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import threading

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SPEC = os.path.join(REPO, "specs", "fig10_gemm.json")
sys.path.insert(0, os.path.join(REPO, "src"))

from repro.campaign.report import check_rows, golden_path, load_json  # noqa: E402
from repro.serve.client import ServeClient  # noqa: E402

POINT = dict(system="tpu-v3",
             estimator={"kind": "systolic", "options": {"preset": "onnxim"}})


def fail(msg: str) -> None:
    print(f"SERVE-SMOKE FAILURE: {msg}")
    raise SystemExit(1)


def coalesced_pair(client: ServeClient) -> None:
    """Two concurrent identical cold requests -> one cold miss, zero
    duplicates."""
    workload = {"name": "smoke-pair", "fidelity": "raw",
                "gemm": {"m": 3000, "n": 3000, "k": 3000, "dtype": "bf16"}}
    before = client.stats()["predict"]
    rows, errs = [], []

    def hit():
        try:
            rows.append(client.predict(workload, **POINT))
        except Exception as e:  # noqa: BLE001
            errs.append(e)

    pair = [threading.Thread(target=hit) for _ in range(2)]
    for t in pair:
        t.start()
    for t in pair:
        t.join()
    if errs:
        fail(f"duplicate-pair request errored: {errs}")
    after = client.stats()["predict"]
    pair_misses = after["cache_misses"] - before["cache_misses"]
    if pair_misses != 1:
        fail(f"duplicate pair recorded {pair_misses} cold misses, "
             "expected exactly 1 (coalescing broken)")
    if after["duplicate_cold_misses"] != 0:
        fail(f"/stats duplicate_cold_misses = "
             f"{after['duplicate_cold_misses']}, expected 0")
    if rows[0]["step_time_s"] != rows[1]["step_time_s"]:
        fail("coalesced pair disagreed on the prediction")
    print(f"  coalesced pair: 1 cold miss, 0 duplicates, "
          f"{after['coalesced'] - before['coalesced']} request(s) waited "
          "on the leader")


def golden_replay(client: ServeClient) -> None:
    """Stream the Fig 10 campaign over HTTP; rows must match the golden
    snapshot bit-for-bit within its tolerance."""
    rows, summary = client.campaign(spec_path=SPEC,
                                    executor="thread").collect()
    if summary is None or summary.get("num_failed", 1) != 0:
        fail(f"served campaign failed: {summary}")
    golden = load_json(golden_path(SPEC, summary["campaign"]))
    if golden is None:
        fail(f"no golden snapshot for {summary['campaign']}")
    check = check_rows(golden, rows)
    if check["failures"]:
        for f in check["failures"]:
            print(f"  golden diff: {f}")
        fail(f"{len(check['failures'])} streamed row(s) deviate from "
             "the golden snapshot")
    print(f"  golden replay: {check['rows_checked']} rows match "
          f"(tolerance {check['tolerance']})")


def report_endpoint(client: ServeClient) -> None:
    rep = client.report(SPEC, check=True)
    failures = rep.get("golden_check", {}).get("failures", ["no check"])
    if failures:
        fail(f"/report golden check failed: {failures}")
    print(f"  /report: golden OK over "
          f"{rep['golden_check']['rows_checked']} rows, "
          f"MAPE table built for {rep['num_ok']} predictions")


def main() -> int:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    daemon = subprocess.Popen(
        [sys.executable, "-m", "repro.serve", "--port", "0",
         "--preload", SPEC],
        cwd=REPO, env=env, stdout=subprocess.PIPE, text=True)
    try:
        boot = daemon.stdout.readline()
        url = json.loads(boot)["url"]
        print(f"daemon up at {url} (pid {daemon.pid})")
        client = ServeClient(url)
        client.wait_ready(timeout_s=30.0)

        coalesced_pair(client)
        golden_replay(client)
        report_endpoint(client)

        st = client.stats()
        if st["predict"]["duplicate_cold_misses"] != 0:
            fail("final /stats shows predict duplicate cold misses")
        if st["campaign"]["duplicate_cold_misses"] != 0:
            fail("final /stats shows campaign duplicate cold misses")
        print(f"  /stats: {st['requests']} · plans resident "
              f"{st['plans']['resident']} · cache entries "
              f"{st['cache']['entries']}")

        client.shutdown()
        rc = daemon.wait(timeout=30)
        if rc != 0:
            fail(f"daemon exited {rc} after graceful shutdown")
        print("serve smoke: all checks passed")
        return 0
    finally:
        if daemon.poll() is None:
            daemon.terminate()
            try:
                daemon.wait(timeout=10)
            except subprocess.TimeoutExpired:
                daemon.kill()


if __name__ == "__main__":
    sys.exit(main())
