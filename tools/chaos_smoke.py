#!/usr/bin/env python3
"""CI chaos smoke: the fleet under a seeded fault plan.

The robustness claim is not "no worker ever dies" — it is "a worker
death changes *nothing observable* except the fleet's restart counters".
This job proves it twice, deterministically:

  1. **Fleet redispatch** — boot ``python -m repro.serve --workers 2``
     with a seeded fault plan that SIGKILLs the worker serving the
     Fig 10 campaign right after its 5th streamed row, plus one
     injected estimator exception on the redispatch target (absorbed by
     ``retries=1``).  The streamed campaign must still be
     golden-identical (``check_rows`` max drift 0.0, all 24 unique
     rows), ``/stats`` must show the restart/redispatch/resume/retry
     counters — and zero duplicate cold misses: the rows the dead
     worker already flushed were write-through to the shared store, so
     the survivor resumes warm instead of recomputing.
  2. **CLI crash + ``--resume``** — run the same campaign locally with
     a fault plan that kills the whole process at row 5 (exit 137,
     partial ``results.jsonl`` on disk), then re-run with ``--resume``:
     the completed grid must be golden-identical too, replaying the 5
     surviving rows instead of recomputing them.

Deterministic counters land in ``BENCH_chaos.json`` and are pinned in
``specs/bench_baselines.json`` via ``tools/bench_check.py``.  Run from
the repo root::

    PYTHONPATH=src python tools/chaos_smoke.py
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SPEC = os.path.join(REPO, "specs", "fig10_gemm.json")
sys.path.insert(0, os.path.join(REPO, "src"))

from repro.campaign.report import check_rows, golden_path, load_json  # noqa: E402
from repro.serve.client import ServeClient  # noqa: E402
from repro.serve.faults import KILL_STATUS  # noqa: E402
from repro.serve.fleet import request_class, route_index  # noqa: E402

WORKERS = 2
KILL_AT_ROW = 5
GRID = 24   # fig10: 6 workloads x 2 systems x 2 estimators

BENCH = {}


def fail(msg: str) -> None:
    print(f"CHAOS-SMOKE FAILURE: {msg}")
    raise SystemExit(1)


def golden_drift(rows: list[dict], campaign: str) -> float:
    """Max drift of ``rows`` vs the checked-in golden snapshot (fails
    the run on any mismatch)."""
    golden = load_json(golden_path(SPEC, campaign))
    if golden is None:
        fail(f"no golden snapshot for {campaign}")
    check = check_rows(golden, rows, tolerance=0.0)
    if check["failures"]:
        for f in check["failures"]:
            print(f"  golden diff: {f}")
        fail(f"{len(check['failures'])} row(s) deviate from golden "
             "after fault injection")
    return check.get("max_drift", 0.0)


def fleet_under_fire(tmp: str) -> None:
    """Part 1: kill the campaign's worker mid-stream; the fleet must
    redispatch, the output must be golden-identical."""
    cls = request_class("/campaign", {"spec_path": SPEC})
    victim = route_index(cls, WORKERS)          # who serves the campaign
    bystander = (victim + 1) % WORKERS          # who inherits it
    plan = {"seed": 2108, "faults": [
        # SIGKILL the serving worker right after its 5th streamed row
        {"site": "campaign_row", "op": "kill", "at": KILL_AT_ROW,
         "worker": victim, "generation": 0},
        # and greet the redispatch target with one estimator exception,
        # absorbed by retries=1
        {"site": "evaluate", "op": "error", "at": 1, "times": 1,
         "worker": bystander, "generation": 0},
    ]}
    plan_path = os.path.join(tmp, "fleet_plan.json")
    with open(plan_path, "w") as f:
        json.dump(plan, f)
    print(f"fleet: campaign routes to worker {victim}; killing it at "
          f"row {KILL_AT_ROW}, injecting 1 estimator error on worker "
          f"{bystander}")

    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    fleet = subprocess.Popen(
        [sys.executable, "-m", "repro.serve", "--port", "0",
         "--workers", str(WORKERS), "--fault-plan", plan_path,
         "--cache", os.path.join(tmp, "hcr.jsonl"), "--preload", SPEC],
        cwd=REPO, env=env, stdout=subprocess.PIPE, text=True)
    try:
        url = json.loads(fleet.stdout.readline())["url"]
        print(f"fleet up at {url} (pid {fleet.pid}, {WORKERS} workers)")
        client = ServeClient(url, timeout_s=120)
        client.wait_ready(timeout_s=60.0)

        rows, summary = client.campaign(spec_path=SPEC, executor="thread",
                                        retries=1).collect()
        ids = sorted(r["job_id"] for r in rows)
        if ids != list(range(GRID)):
            fail(f"streamed grid incomplete/duplicated: {len(rows)} "
                 f"rows, {len(set(ids))} unique ids")
        bad = [r for r in rows if "error" in r]
        if bad:
            fail(f"{len(bad)} error row(s) survived redispatch+retry: "
                 f"{bad[0]}")
        drift = golden_drift(rows, summary["campaign"])
        print(f"  campaign: {len(rows)} rows golden-identical "
              f"(max drift {drift})")

        st = client.stats()
        fl, totals = st["fleet"], st["totals"]
        if fl["restarts"] < 1:
            fail(f"expected >=1 restart, fleet counters: {fl}")
        if fl["redispatches"] != 1:
            fail(f"expected exactly 1 redispatch, got "
                 f"{fl['redispatches']}")
        if fl["degraded"] != 0:
            fail(f"nothing should have degraded, got {fl['degraded']}")
        if totals["duplicate_cold_misses"] != 0:
            fail(f"duplicate cold misses after redispatch: "
                 f"{totals['duplicate_cold_misses']} (write-through "
                 "resume broken)")
        if totals["resumed_rows"] != KILL_AT_ROW:
            fail(f"expected {KILL_AT_ROW} resumed rows, got "
                 f"{totals['resumed_rows']}")
        if totals["retried_rows"] != 1:
            fail(f"expected 1 retried row, got {totals['retried_rows']}")
        print(f"  /stats: restarts={fl['restarts']} "
              f"redispatches={fl['redispatches']} "
              f"resumed={totals['resumed_rows']} "
              f"retried={totals['retried_rows']} "
              f"duplicate_cold_misses={totals['duplicate_cold_misses']}")
        BENCH["fleet"] = {
            "workers": WORKERS,
            "restarts": fl["restarts"],
            "worker_deaths": fl["worker_deaths"],
            "redispatches": fl["redispatches"],
            "degraded": fl["degraded"],
            "rows": len(rows),
            "resumed_rows": totals["resumed_rows"],
            "retried_rows": totals["retried_rows"],
            "duplicate_cold_misses": totals["duplicate_cold_misses"],
            "max_drift": drift,
        }

        client.shutdown()
        rc = fleet.wait(timeout=60)
        if rc != 0:
            fail(f"fleet exited {rc} after graceful shutdown")
    finally:
        if fleet.poll() is None:
            fleet.terminate()
            try:
                fleet.wait(timeout=10)
            except subprocess.TimeoutExpired:
                fleet.kill()


def cli_resume_after_kill(tmp: str) -> None:
    """Part 2: hard-kill the campaign CLI mid-run, then ``--resume``;
    the completed artifacts must be golden-identical."""
    plan = {"seed": 2108, "faults": [
        {"site": "campaign_row", "op": "kill", "at": KILL_AT_ROW}]}
    plan_path = os.path.join(tmp, "cli_plan.json")
    with open(plan_path, "w") as f:
        json.dump(plan, f)
    out = os.path.join(tmp, "campaign")

    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    base = [sys.executable, "-m", "repro.campaign", "run", SPEC,
            "--out", out, "--executor", "serial", "--quiet",
            "--cache", os.path.join(tmp, "cli_hcr.jsonl")]
    rc = subprocess.run(base + ["--fault-plan", plan_path],
                        cwd=REPO, env=env).returncode
    if rc != KILL_STATUS:
        fail(f"faulted run should die with status {KILL_STATUS}, "
             f"got {rc}")
    jsonl = os.path.join(out, "results.jsonl")
    partial = [json.loads(line) for line in open(jsonl)]   # must parse
    if len(partial) != KILL_AT_ROW:
        fail(f"expected {KILL_AT_ROW} flushed rows in the partial "
             f"results.jsonl, found {len(partial)}")
    print(f"cli: killed at row {KILL_AT_ROW} (exit {rc}), "
          f"results.jsonl parseable with {len(partial)} rows")

    rc = subprocess.run(base + ["--resume"], cwd=REPO, env=env).returncode
    if rc != 0:
        fail(f"--resume run exited {rc}")
    rows = [json.loads(line) for line in open(jsonl)]
    ids = sorted(r["job_id"] for r in rows)
    if ids != list(range(GRID)):
        fail(f"resumed grid incomplete: {len(rows)} rows")
    resumed = sum(1 for r in rows if r.get("resumed"))
    if resumed != KILL_AT_ROW:
        fail(f"expected {KILL_AT_ROW} replayed rows, got {resumed}")
    summary = json.load(open(os.path.join(out, "summary.json")))
    drift = golden_drift(rows, summary["campaign"])
    print(f"  --resume: grid complete, {resumed} rows replayed, "
          f"golden-identical (max drift {drift})")
    BENCH["resume_cli"] = {
        "exit_status_on_kill": KILL_STATUS,
        "partial_rows": len(partial),
        "rows_after_resume": len(rows),
        "rows_replayed": resumed,
        "max_drift": drift,
    }


def main() -> int:
    with tempfile.TemporaryDirectory(prefix="chaos-smoke-") as tmp:
        fleet_under_fire(tmp)
        cli_resume_after_kill(tmp)
    bench_path = os.path.join(REPO, "BENCH_chaos.json")
    with open(bench_path, "w") as f:
        json.dump(BENCH, f, indent=2)
    print(f"chaos smoke: all checks passed; counters -> {bench_path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
